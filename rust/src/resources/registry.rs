//! The open policy registry — "the users can easily mount a newly
//! designed algorithm module" (§1), made literal.
//!
//! A [`PolicyRegistry`] maps string names (plus aliases) to factory
//! closures that turn a [`PolicySpec`] (name + numeric params, carried
//! by `config::AllocConfig`) into a boxed [`Policy`]. The process-wide
//! registry starts with the five built-ins (`adaptive`, `baseline`,
//! `static-headroom`, `rate-capped`, `predictive`); mounting a new
//! policy is one call:
//!
//! ```
//! use kubeadaptor::resources::registry;
//! use kubeadaptor::resources::FcfsPolicy;
//!
//! registry::register_policy("my-policy", &[], "always the raw request", |_spec, _alloc| {
//!     Ok(Box::new(FcfsPolicy::new()))
//! })
//! .unwrap();
//! // From here `--policy my-policy`, config files and campaign grids
//! // all resolve it.
//! ```
//!
//! Unknown names fail at build time with the list of registered
//! policies; unknown params fail inside the factory (each built-in
//! validates its accepted keys).
//!
//! **Aliases are an input convenience, not an identity.** The registry
//! resolves them (case-insensitively) when *building*, but report
//! grouping and the campaign duplicate-axis check compare `PolicySpec`
//! values — use canonical names in programmatic specs. The legacy
//! `aras`/`fcfs` spellings are special-cased in
//! [`PolicySpec::named`]/[`PolicySpec::parse`] (kept in lockstep with
//! the builtin alias lists below); aliases of user-registered policies
//! are not rewritten there.

use std::sync::{OnceLock, RwLock};

use super::headroom::{StaticHeadroomPolicy, DEFAULT_HEADROOM};
use super::predictive::PredictivePolicy;
use super::rate_capped::{RateCappedPolicy, DEFAULT_BUDGET};
use super::{AdaptivePolicy, FcfsPolicy, Policy};
use crate::config::AllocConfig;

pub use crate::config::PolicySpec;

/// Factory signature: spec (parsed name + params) and the run's
/// allocation config (α, lookahead, β, … — the shared knobs).
pub type PolicyFactory =
    Box<dyn Fn(&PolicySpec, &AllocConfig) -> anyhow::Result<Box<dyn Policy>> + Send + Sync>;

/// One registered policy.
pub struct PolicyEntry {
    pub name: String,
    pub aliases: Vec<String>,
    /// One-line description for `--list-policies`.
    pub summary: String,
    factory: PolicyFactory,
}

impl PolicyEntry {
    fn matches(&self, name: &str) -> bool {
        self.name.eq_ignore_ascii_case(name)
            || self.aliases.iter().any(|a| a.eq_ignore_ascii_case(name))
    }
}

/// String-keyed policy registry.
#[derive(Default)]
pub struct PolicyRegistry {
    entries: Vec<PolicyEntry>,
}

impl PolicyRegistry {
    /// An empty registry (library embedders composing their own set).
    pub fn empty() -> Self {
        Self::default()
    }

    /// A registry pre-loaded with the five built-in policies.
    pub fn with_builtins() -> Self {
        let mut r = Self::empty();
        r.register(
            "adaptive",
            &["aras"],
            "ARAS (Alg. 1-3, Eq. 9): lifecycle-window demand scaling [params: alpha, lookahead]",
            |spec, alloc| {
                check_params(spec, &["alpha", "lookahead"])?;
                Ok(Box::new(build_adaptive(spec, alloc)?))
            },
        )
        .expect("builtin registration");
        r.register(
            "baseline",
            &["fcfs"],
            "FCFS baseline [21]: full requests, resync-timer monitoring only",
            |spec, _alloc| {
                check_params(spec, &[])?;
                Ok(Box::new(FcfsPolicy::new()))
            },
        )
        .expect("builtin registration");
        r.register(
            "static-headroom",
            &[],
            "fixed over-provisioning baseline: request x headroom [params: headroom]",
            |spec, _alloc| {
                check_params(spec, &["headroom"])?;
                let headroom = spec.param("headroom").unwrap_or(DEFAULT_HEADROOM);
                Ok(Box::new(StaticHeadroomPolicy::new(headroom)?))
            },
        )
        .expect("builtin registration");
        r.register(
            "rate-capped",
            &[],
            "ARAS with a scaling budget per planning call [params: budget, alpha, lookahead]",
            |spec, alloc| {
                check_params(spec, &["budget", "alpha", "lookahead"])?;
                let budget = spec.param("budget").unwrap_or(DEFAULT_BUDGET as f64);
                anyhow::ensure!(
                    budget >= 0.0 && budget.fract() == 0.0,
                    "rate-capped budget must be a non-negative integer, got {budget}"
                );
                let inner = build_adaptive(spec, alloc)?;
                Ok(Box::new(RateCappedPolicy::with_inner(inner, budget as usize)))
            },
        )
        .expect("builtin registration");
        r.register(
            "predictive",
            &[],
            "ARAS + forecast demand: each window also pays for predicted arrivals \
             [params: weight, alpha, lookahead]",
            |spec, alloc| {
                check_params(spec, &["weight", "alpha", "lookahead"])?;
                let weight = spec.param("weight").unwrap_or(PredictivePolicy::DEFAULT_WEIGHT);
                let inner = build_adaptive(spec, alloc)?;
                Ok(Box::new(PredictivePolicy::new(inner, weight)?))
            },
        )
        .expect("builtin registration");
        r
    }

    /// Mount a policy: `name` (and each alias) must not collide with an
    /// existing entry.
    pub fn register(
        &mut self,
        name: impl Into<String>,
        aliases: &[&str],
        summary: impl Into<String>,
        factory: impl Fn(&PolicySpec, &AllocConfig) -> anyhow::Result<Box<dyn Policy>>
            + Send
            + Sync
            + 'static,
    ) -> anyhow::Result<()> {
        let name = name.into().to_lowercase();
        anyhow::ensure!(!name.is_empty(), "policy name must be non-empty");
        for candidate in std::iter::once(name.as_str()).chain(aliases.iter().copied()) {
            anyhow::ensure!(
                self.resolve(candidate).is_none(),
                "policy name '{candidate}' is already registered"
            );
        }
        self.entries.push(PolicyEntry {
            name,
            aliases: aliases.iter().map(|a| a.to_lowercase()).collect(),
            summary: summary.into(),
            factory: Box::new(factory),
        });
        Ok(())
    }

    /// Look an entry up by name or alias (case-insensitive).
    pub fn resolve(&self, name: &str) -> Option<&PolicyEntry> {
        self.entries.iter().find(|e| e.matches(name))
    }

    /// Canonical name for a spelling (alias → primary name).
    pub fn canonical_name(&self, name: &str) -> Option<&str> {
        self.resolve(name).map(|e| e.name.as_str())
    }

    /// Instantiate the policy a spec describes.
    pub fn build(&self, spec: &PolicySpec, alloc: &AllocConfig) -> anyhow::Result<Box<dyn Policy>> {
        let entry = self.resolve(&spec.name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown policy '{}' (registered: {})",
                spec.name,
                self.names().join(", ")
            )
        })?;
        (entry.factory)(spec, alloc)
            .map_err(|e| anyhow::anyhow!("building policy '{}': {e}", entry.name))
    }

    /// Registered canonical names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    /// (name, aliases, summary) rows for `--list-policies`, sorted by
    /// name so the roster prints deterministically regardless of
    /// registration order.
    pub fn listing(&self) -> Vec<(String, Vec<String>, String)> {
        let mut rows: Vec<(String, Vec<String>, String)> = self
            .entries
            .iter()
            .map(|e| (e.name.clone(), e.aliases.clone(), e.summary.clone()))
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }

    pub fn entries(&self) -> &[PolicyEntry] {
        &self.entries
    }
}

// ------------------------------------------------------- global registry

static GLOBAL: OnceLock<RwLock<PolicyRegistry>> = OnceLock::new();

/// The process-wide registry (built-ins pre-registered on first use).
pub fn global() -> &'static RwLock<PolicyRegistry> {
    GLOBAL.get_or_init(|| RwLock::new(PolicyRegistry::with_builtins()))
}

/// Mount a policy into the global registry — the "one registration
/// call" path for downstream algorithm modules.
pub fn register_policy(
    name: impl Into<String>,
    aliases: &[&str],
    summary: impl Into<String>,
    factory: impl Fn(&PolicySpec, &AllocConfig) -> anyhow::Result<Box<dyn Policy>>
        + Send
        + Sync
        + 'static,
) -> anyhow::Result<()> {
    global().write().unwrap().register(name, aliases, summary, factory)
}

/// Instantiate `spec` via the global registry.
pub fn build_policy(spec: &PolicySpec, alloc: &AllocConfig) -> anyhow::Result<Box<dyn Policy>> {
    global().read().unwrap().build(spec, alloc)
}

/// Canonical names registered globally, in registration order.
pub fn policy_names() -> Vec<String> {
    global().read().unwrap().names()
}

/// Sorted (name, aliases, summary) rows for `--list-policies`.
pub fn policy_listing() -> Vec<(String, Vec<String>, String)> {
    global().read().unwrap().listing()
}

/// Shared assembly of the ARAS core used by `adaptive`, `rate-capped`
/// and `predictive`: resolves alpha/lookahead (spec param over alloc
/// config) and wires the numeric backend through
/// [`super::backends::build`] — the single place `alloc.backend` is
/// honored, so scalar, native and PJRT runs share identical parameter
/// semantics for every ARAS-based policy.
fn build_adaptive(spec: &PolicySpec, alloc: &AllocConfig) -> anyhow::Result<AdaptivePolicy> {
    let alpha = spec.param("alpha").unwrap_or(alloc.alpha);
    anyhow::ensure!(alpha > 0.0 && alpha <= 1.0, "alpha in (0,1], got {alpha}");
    let lookahead = spec.param("lookahead").map(|v| v != 0.0).unwrap_or(alloc.lookahead);
    Ok(AdaptivePolicy::new(alpha, lookahead).with_backend(super::backends::build(alloc.backend)?))
}

/// Reject params a policy does not understand (typo protection).
fn check_params(spec: &PolicySpec, allowed: &[&str]) -> anyhow::Result<()> {
    for (key, _) in &spec.params {
        anyhow::ensure!(
            allowed.contains(&key.as_str()),
            "policy '{}' has no parameter '{}'{}",
            spec.name,
            key,
            if allowed.is_empty() {
                " (it takes none)".to_string()
            } else {
                format!(" (accepted: {})", allowed.join(", "))
            }
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc() -> AllocConfig {
        AllocConfig::default()
    }

    #[test]
    fn builtins_resolve_by_name_and_alias() {
        let r = PolicyRegistry::with_builtins();
        assert_eq!(
            r.names(),
            vec!["adaptive", "baseline", "static-headroom", "rate-capped", "predictive"]
        );
        assert_eq!(r.canonical_name("ARAS"), Some("adaptive"));
        assert_eq!(r.canonical_name("fcfs"), Some("baseline"));
        assert!(r.resolve("nope").is_none());
    }

    #[test]
    fn listing_is_sorted_regardless_of_registration_order() {
        let mut r = PolicyRegistry::with_builtins();
        // Registered last, sorts first.
        r.register("aaa-policy", &[], "test", |_s, _a| Ok(Box::new(FcfsPolicy::new())))
            .unwrap();
        let names: Vec<&str> = r.listing().iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec!["aaa-policy", "adaptive", "baseline", "predictive", "rate-capped", "static-headroom"]
        );
    }

    #[test]
    fn predictive_builds_and_validates_weight() {
        let r = PolicyRegistry::with_builtins();
        let mut p = r.build(&PolicySpec::named("predictive"), &alloc()).unwrap();
        assert_eq!(p.name(), "predictive");
        // Without a snapshot forecast it plans exactly like ARAS.
        let req = crate::resources::TaskRequest {
            task_id: "t".into(),
            req_cpu: 2000.0,
            req_mem: 4000.0,
            min_cpu: 200.0,
            min_mem: 1000.0,
            win_start: 0.0,
            win_end: 15.0,
        };
        let snap = crate::resources::ClusterSnapshot::from_residuals(
            crate::resources::ResidualMap::default(),
        );
        let d = p.plan(&[req], &snap, &crate::statestore::StateStore::new())[0];
        assert!(d.cpu_milli <= 2000);
        let bad = PolicySpec::named("predictive").with_param("weight", -1.0);
        assert!(r.build(&bad, &alloc()).is_err());
    }

    #[test]
    fn build_reports_unknown_names_with_the_roster() {
        let r = PolicyRegistry::with_builtins();
        let err = r.build(&PolicySpec::named("nope"), &alloc()).unwrap_err().to_string();
        assert!(err.contains("unknown policy 'nope'"), "{err}");
        assert!(err.contains("adaptive"), "{err}");
    }

    #[test]
    fn params_flow_into_factories() {
        let r = PolicyRegistry::with_builtins();
        let mut p = r
            .build(&PolicySpec::named("static-headroom").with_param("headroom", 1.5), &alloc())
            .unwrap();
        assert_eq!(p.name(), "static-headroom");
        // A 1.5x headroom on 2000m shows up in the decision.
        let req = crate::resources::TaskRequest {
            task_id: "t".into(),
            req_cpu: 2000.0,
            req_mem: 4000.0,
            min_cpu: 200.0,
            min_mem: 1000.0,
            win_start: 0.0,
            win_end: 15.0,
        };
        let snap = crate::resources::ClusterSnapshot::from_residuals(
            crate::resources::ResidualMap::default(),
        );
        let d = p.plan(&[req], &snap, &crate::statestore::StateStore::new())[0];
        assert_eq!(d.cpu_milli, 3000);
    }

    #[test]
    fn unknown_params_are_rejected() {
        let r = PolicyRegistry::with_builtins();
        let err = r
            .build(&PolicySpec::named("baseline").with_param("zeal", 9.0), &alloc())
            .unwrap_err()
            .to_string();
        assert!(err.contains("no parameter 'zeal'"), "{err}");
        assert!(r
            .build(&PolicySpec::adaptive().with_param("warp", 1.0), &alloc())
            .is_err());
    }

    #[test]
    fn adaptive_param_overrides_alloc_config() {
        let r = PolicyRegistry::with_builtins();
        let bad = r.build(&PolicySpec::adaptive().with_param("alpha", 0.0), &alloc());
        assert!(bad.is_err(), "alpha=0 must be rejected at build");
        assert!(r.build(&PolicySpec::adaptive().with_param("alpha", 0.5), &alloc()).is_ok());
    }

    #[test]
    fn duplicate_registration_is_rejected() {
        let mut r = PolicyRegistry::with_builtins();
        let err = r
            .register("aras", &[], "dup", |_s, _a| Ok(Box::new(FcfsPolicy::new())))
            .unwrap_err()
            .to_string();
        assert!(err.contains("already registered"), "{err}");
    }

    #[test]
    fn custom_registration_round_trips() {
        let mut r = PolicyRegistry::empty();
        r.register("mine", &["m"], "test policy", |_s, _a| Ok(Box::new(FcfsPolicy::new())))
            .unwrap();
        let p = r.build(&PolicySpec::named("m"), &alloc()).unwrap();
        assert_eq!(p.name(), "baseline"); // the policy it wraps
    }

    #[test]
    fn rate_capped_budget_must_be_integral() {
        let r = PolicyRegistry::with_builtins();
        let fractional = PolicySpec::named("rate-capped").with_param("budget", 2.5);
        assert!(r.build(&fractional, &alloc()).is_err());
        let whole = PolicySpec::named("rate-capped").with_param("budget", 3.0);
        assert!(r.build(&whole, &alloc()).is_ok());
    }
}
