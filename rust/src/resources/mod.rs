//! Resource Manager — the paper's contribution (§5).
//!
//! Sub-modules mirror Fig. 2's decomposition:
//!
//! * [`discovery`] — Resource Discovery (Algorithm 2): build the
//!   ResidualMap from the Informer's cached pod/node lists.
//! * [`evaluator`] — Resource Evaluator (Algorithm 3 + Eq. 9): the
//!   four-regime scaling decision, implemented in f32 to stay bit-exact
//!   with the Pallas kernel / PJRT path.
//! * [`adaptive`]  — the ARAS driver (Algorithm 1): lifecycle-window
//!   demand aggregation + discovery + evaluation.
//! * [`baseline`]  — the FCFS baseline from the authors' prior work [21].
//!
//! Policies are swappable behind the [`Policy`] trait ("the users can
//! easily mount a newly designed algorithm module", §1).

pub mod adaptive;
pub mod baseline;
pub mod discovery;
pub mod evaluator;

pub use adaptive::AdaptivePolicy;
pub use baseline::FcfsPolicy;
pub use discovery::{discover, ResidualMap};

use crate::simcore::SimTime;
use crate::statestore::StateStore;

/// A task pod's resource request, as handed to the Resource Manager by
/// the Containerized Executor.
#[derive(Debug, Clone)]
pub struct TaskRequest {
    /// Unique task id (key into the state store).
    pub task_id: String,
    /// Requested CPU, milli-cores (Eq. 1 `cpu`).
    pub req_cpu: f64,
    /// Requested memory, Mi (Eq. 1 `mem`).
    pub req_mem: f64,
    /// Minimum viable CPU (Eq. 1 `min_cpu`).
    pub min_cpu: f64,
    /// Minimum viable memory (Eq. 1 `min_mem`).
    pub min_mem: f64,
    /// Lifecycle window [t_start, t_end) for the lookahead scan.
    pub win_start: SimTime,
    pub win_end: SimTime,
}

/// The Resource Manager's answer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    /// Allocated CPU request (milli-cores, floored like kubelet does).
    pub cpu_milli: i64,
    /// Allocated memory request (Mi).
    pub mem_mi: i64,
    /// Aggregated demand diagnostics (Alg. 1's request.cpu/request.mem).
    pub request_cpu: f64,
    pub request_mem: f64,
}

impl Decision {
    /// Whether the allocation meets the minimum running resources
    /// (Algorithm 1 line 27: `alloc_cpu >= min_cpu && alloc_mem >= min_mem + β`).
    pub fn meets_minimum(&self, min_cpu: f64, min_mem: f64, beta: f64) -> bool {
        self.cpu_milli as f64 >= min_cpu && self.mem_mi as f64 >= min_mem + beta
    }
}

/// A pluggable resource-allocation policy.
pub trait Policy {
    fn name(&self) -> &'static str;

    /// Decide the resource quota for one task request given the current
    /// ResidualMap and the workflow state store.
    fn allocate(
        &mut self,
        req: &TaskRequest,
        residuals: &ResidualMap,
        store: &StateStore,
    ) -> Decision;

    /// Whether the policy ships the paper's Informer-based "novel
    /// monitoring mechanism" (§1): waiting requests are re-served the
    /// moment resources are released. The FCFS baseline [21] predates it
    /// and only retries on a periodic resync timer — the reaction latency
    /// Fig. 9 exhibits (~30 s between deletion and reallocation).
    fn reactive_monitoring(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meets_minimum_applies_beta() {
        let d = Decision { cpu_milli: 500, mem_mi: 1019, request_cpu: 0.0, request_mem: 0.0 };
        assert!(!d.meets_minimum(200.0, 1000.0, 20.0)); // 1019 < 1020
        assert!(d.meets_minimum(200.0, 1000.0, 19.0));
        assert!(!d.meets_minimum(501.0, 1000.0, 19.0));
    }
}
