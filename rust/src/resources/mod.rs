//! Resource Manager — the paper's contribution (§5).
//!
//! Sub-modules mirror Fig. 2's decomposition:
//!
//! * [`discovery`] — Resource Discovery (Algorithm 2): build the
//!   ResidualMap from the Informer's cached pod/node lists.
//! * [`evaluator`] — Resource Evaluator (Algorithm 3 + Eq. 9): the
//!   four-regime scaling decision, implemented in f32 to stay bit-exact
//!   with the Pallas kernel / PJRT path.
//! * [`adaptive`]  — the ARAS driver (Algorithm 1): lifecycle-window
//!   demand aggregation + discovery + evaluation.
//! * [`baseline`]  — the FCFS baseline from the authors' prior work [21].
//! * [`headroom`]  — `static-headroom`: fixed over-provisioning baseline.
//! * [`rate_capped`] — `rate-capped`: ARAS with a per-cycle scaling budget.
//! * [`predictive`] — `predictive`: ARAS whose lifecycle-window demand is
//!   augmented by the run's [`crate::forecast`] demand forecast.
//! * [`registry`]  — the open, string-keyed policy registry ("the users
//!   can easily mount a newly designed algorithm module", §1): one
//!   [`registry::register_policy`] call makes a policy reachable from
//!   configs, campaigns and the CLI.
//! * [`backends`]  — the decision-backend roster (`scalar` | `native` |
//!   `pjrt`): resolves `--backend` / config `"backend"` to a live
//!   [`adaptive::DecisionBackend`] for every ARAS-based policy.
//!
//! ## The v2 policy contract
//!
//! Policies implement the batched, snapshot-driven [`Policy`] trait: the
//! engine takes **one** [`ClusterSnapshot`] per queue-serve cycle and
//! hands the policy every admissible queue head at once
//! ([`Policy::plan`]) — the same batch shape the Pallas `alloc_eval`
//! kernel is lowered with, so the PJRT backend executes whole cycles in
//! single device calls. Lifecycle hooks ([`Policy::on_release`],
//! [`Policy::on_oom`], [`Policy::on_tick`]) let stateful policies track
//! cluster churn between cycles without polling.

pub mod adaptive;
pub mod backends;
pub mod baseline;
pub mod discovery;
pub mod evaluator;
pub mod headroom;
pub mod predictive;
pub mod rate_capped;
pub mod registry;

pub use adaptive::AdaptivePolicy;
pub use baseline::FcfsPolicy;
pub use discovery::{discover, ResidualMap};
pub use headroom::StaticHeadroomPolicy;
pub use predictive::PredictivePolicy;
pub use rate_capped::RateCappedPolicy;
pub use registry::{PolicyRegistry, PolicySpec};

use crate::cluster::{Informer, ObjectStore};
use crate::simcore::SimTime;
use crate::statestore::StateStore;

/// A task pod's resource request, as handed to the Resource Manager by
/// the Containerized Executor.
#[derive(Debug, Clone)]
pub struct TaskRequest {
    /// Unique task id (key into the state store).
    pub task_id: String,
    /// Requested CPU, milli-cores (Eq. 1 `cpu`).
    pub req_cpu: f64,
    /// Requested memory, Mi (Eq. 1 `mem`).
    pub req_mem: f64,
    /// Minimum viable CPU (Eq. 1 `min_cpu`).
    pub min_cpu: f64,
    /// Minimum viable memory (Eq. 1 `min_mem`).
    pub min_mem: f64,
    /// Lifecycle window [t_start, t_end) for the lookahead scan.
    pub win_start: SimTime,
    pub win_end: SimTime,
}

/// The Resource Manager's answer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    /// Allocated CPU request (milli-cores, floored like kubelet does).
    pub cpu_milli: i64,
    /// Allocated memory request (Mi).
    pub mem_mi: i64,
    /// Aggregated demand diagnostics (Alg. 1's request.cpu/request.mem).
    pub request_cpu: f64,
    pub request_mem: f64,
}

impl Decision {
    /// Whether the allocation meets the minimum running resources
    /// (Algorithm 1 line 27: `alloc_cpu >= min_cpu && alloc_mem >= min_mem + β`).
    pub fn meets_minimum(&self, min_cpu: f64, min_mem: f64, beta: f64) -> bool {
        self.cpu_milli as f64 >= min_cpu && self.mem_mi as f64 >= min_mem + beta
    }
}

/// One consistent view of the cluster, taken exactly once per
/// queue-serve cycle: the Resource Discovery output (Algorithm 2)
/// bundled with the Informer metadata it was derived from. Every
/// request the engine serves in a cycle sees the same snapshot — pods
/// created inside the cycle are not yet visible in the cache (informer
/// semantics), which lets Eq. (9) partition one residual across a whole
/// admission wave.
#[derive(Debug, Clone)]
pub struct ClusterSnapshot {
    /// Per-node residuals (Algorithm 2's dictionary).
    pub residuals: ResidualMap,
    /// Virtual time the snapshot was captured.
    pub taken_at: SimTime,
    /// Informer cache resource version after the sync.
    pub resource_version: u64,
    /// Watch events drained by the sync that produced this snapshot.
    pub watch_events_applied: usize,
    /// Pods in the informer cache at capture (all phases).
    pub pods_cached: usize,
    /// Nodes in the informer cache at capture.
    pub nodes_cached: usize,
    /// Demand forecast the engine attaches when a forecaster is
    /// configured (`None` otherwise, and until the forecaster has its
    /// first observation). Policies are free to ignore it — only
    /// `predictive` reads it today.
    pub forecast: Option<crate::forecast::DemandForecast>,
}

impl ClusterSnapshot {
    /// Monitor phase of one reconcile cycle: drain the watch stream into
    /// the informer cache (one apiserver read round-trip, counted by the
    /// store) and run Resource Discovery over the refreshed cache.
    pub fn capture(informer: &mut Informer, store: &ObjectStore, now: SimTime) -> Self {
        let watch_events_applied = informer.sync(store);
        ClusterSnapshot {
            residuals: discover(informer),
            taken_at: now,
            resource_version: informer.synced_version(),
            watch_events_applied,
            pods_cached: informer.pod_count(),
            nodes_cached: informer.node_count(),
            forecast: None,
        }
    }

    /// A snapshot *without* the sync: Resource Discovery over whatever
    /// the informer cache last saw. The engine uses this while a chaos
    /// `partition` (or a `latency-storm` suppressing this cycle's sync)
    /// cuts the informer off from the store — the snapshot is then
    /// *stale*, and decisions planned on it carry the real informer's
    /// double-allocation risk.
    pub fn capture_stale(informer: &Informer, now: SimTime) -> Self {
        ClusterSnapshot {
            residuals: discover(informer),
            taken_at: now,
            resource_version: informer.synced_version(),
            watch_events_applied: 0,
            pods_cached: informer.pod_count(),
            nodes_cached: informer.node_count(),
            forecast: None,
        }
    }

    /// A snapshot from a bare ResidualMap (tests, synthetic drivers).
    pub fn from_residuals(residuals: ResidualMap) -> Self {
        let nodes_cached = residuals.entries.len();
        ClusterSnapshot {
            residuals,
            taken_at: 0.0,
            resource_version: 0,
            watch_events_applied: 0,
            pods_cached: 0,
            nodes_cached,
            forecast: None,
        }
    }
}

/// A pluggable resource-allocation policy (Resource Manager API v2).
///
/// The engine serves its strict-FCFS allocation queue in cycles: one
/// [`ClusterSnapshot`] per cycle, one [`Policy::plan`] call over every
/// admissible head, then launches in queue order until the first head
/// that must wait. `plan` must return exactly one [`Decision`] per
/// batch entry, in order; decisions beyond the first waiting head are
/// discarded (the engine re-plans next cycle with fresh state).
///
/// **Sequential-equivalence contract** (for *request-scoped* policies):
/// `plan(batch)` must equal the sequence of single-request calls
/// `plan(&batch[i..=i])` made against a store in which the records of
/// batch members `0..i` have been refreshed to their request windows —
/// i.e. batching is a pure amortization. ARAS, FCFS and
/// `static-headroom` honor this; `rust/tests/policy_v2.rs`
/// property-checks it for ARAS and FCFS, and the engine relies on it
/// to probe a stalled head without re-planning the whole queue.
///
/// Policies may instead be deliberately *cycle-scoped* — reading batch
/// structure as signal (e.g. `rate-capped`'s per-cycle scaling budget
/// applies across the batch it is given). Such policies must document
/// the deviation and must still return per-request decisions that are
/// valid if the engine serves only a prefix.
pub trait Policy {
    fn name(&self) -> &str;

    /// Decide resource quotas for a whole queue-serve cycle: one
    /// decision per request in `batch`, all against the same `snapshot`
    /// and workflow state `store`.
    fn plan(
        &mut self,
        batch: &[TaskRequest],
        snapshot: &ClusterSnapshot,
        store: &StateStore,
    ) -> Vec<Decision>;

    /// Resources were released (pod succeeded or was deleted). Called
    /// before the queue wakeup the release triggers.
    fn on_release(&mut self, _now: SimTime) {}

    /// A pod of `task_id` was OOM-killed (§6.2.2 failure path); the task
    /// will be reallocated after cleanup.
    fn on_oom(&mut self, _task_id: &str, _now: SimTime) {}

    /// Periodic metrics tick (the engine's sampling cadence).
    fn on_tick(&mut self, _now: SimTime) {}

    /// Whether the policy ships the paper's Informer-based "novel
    /// monitoring mechanism" (§1): waiting requests are re-served the
    /// moment resources are released. The FCFS baseline [21] predates it
    /// and only retries on a periodic resync timer — the reaction latency
    /// Fig. 9 exhibits (~30 s between deletion and reallocation).
    fn reactive_monitoring(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meets_minimum_applies_beta() {
        let d = Decision { cpu_milli: 500, mem_mi: 1019, request_cpu: 0.0, request_mem: 0.0 };
        assert!(!d.meets_minimum(200.0, 1000.0, 20.0)); // 1019 < 1020
        assert!(d.meets_minimum(200.0, 1000.0, 19.0));
        assert!(!d.meets_minimum(501.0, 1000.0, 19.0));
    }

    #[test]
    fn meets_minimum_exact_mem_boundary_is_inclusive() {
        // Alg. 1 line 27 uses >=: alloc_mem == min_mem + β exactly passes.
        let d = Decision { cpu_milli: 500, mem_mi: 1020, request_cpu: 0.0, request_mem: 0.0 };
        assert!(d.meets_minimum(200.0, 1000.0, 20.0)); // 1020 == 1000 + 20
        assert!(!d.meets_minimum(200.0, 1000.0, 20.5)); // 1020 < 1020.5
        // One Mi below the boundary fails.
        let below = Decision { mem_mi: 1019, ..d };
        assert!(!below.meets_minimum(200.0, 1000.0, 20.0));
    }

    #[test]
    fn meets_minimum_exact_cpu_boundary_is_inclusive() {
        let d = Decision { cpu_milli: 200, mem_mi: 4000, request_cpu: 0.0, request_mem: 0.0 };
        assert!(d.meets_minimum(200.0, 1000.0, 20.0)); // cpu == min_cpu
        let below = Decision { cpu_milli: 199, ..d };
        assert!(!below.meets_minimum(200.0, 1000.0, 20.0));
    }

    #[test]
    fn meets_minimum_beta_zero_degenerates_to_min_mem() {
        let d = Decision { cpu_milli: 200, mem_mi: 1000, request_cpu: 0.0, request_mem: 0.0 };
        assert!(d.meets_minimum(200.0, 1000.0, 0.0));
        assert!(!d.meets_minimum(200.0, 1000.0, 1.0));
    }

    #[test]
    fn snapshot_from_residuals_records_node_count() {
        use discovery::NodeResidual;
        let snap = ClusterSnapshot::from_residuals(ResidualMap {
            entries: vec![NodeResidual {
                ip: "10.0.0.0".into(),
                name: "node-0".into(),
                pool: "node".into(),
                residual_cpu: 8000.0,
                residual_mem: 16384.0,
            }],
        });
        assert_eq!(snap.nodes_cached, 1);
        assert_eq!(snap.residuals.total_cpu(), 8000.0);
    }
}
