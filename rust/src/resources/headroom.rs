//! `static-headroom` — a fixed over-provisioning baseline.
//!
//! Allocates every request scaled *up* by a constant headroom factor
//! (default 1.2×), the classic "pad every pod and hope" operating
//! practice ARAS replaces. It ignores both the cluster snapshot and the
//! state store, so it brackets the ablation grid from the opposite side
//! of FCFS: FCFS under-reacts (exact requests, head-of-line waits),
//! static headroom over-reacts (inflated requests exhaust residuals
//! sooner). Registered in [`super::registry`] as a registry-proving
//! policy: it exists entirely outside the engine/config/campaign code.

use super::{ClusterSnapshot, Decision, Policy, TaskRequest};
use crate::statestore::StateStore;

/// Default over-provisioning factor (20% above the declared request —
/// the kubelet-community rule of thumb for burstable sizing).
pub const DEFAULT_HEADROOM: f64 = 1.2;

#[derive(Debug)]
pub struct StaticHeadroomPolicy {
    headroom: f64,
    decisions: u64,
}

impl StaticHeadroomPolicy {
    /// `headroom` must be >= 1.0 (it is an over-provisioning factor).
    pub fn new(headroom: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(
            headroom >= 1.0 && headroom.is_finite(),
            "static-headroom factor must be >= 1.0, got {headroom}"
        );
        Ok(Self { headroom, decisions: 0 })
    }

    pub fn headroom(&self) -> f64 {
        self.headroom
    }

    pub fn decision_count(&self) -> u64 {
        self.decisions
    }
}

impl Policy for StaticHeadroomPolicy {
    fn name(&self) -> &str {
        "static-headroom"
    }

    fn plan(
        &mut self,
        batch: &[TaskRequest],
        _snapshot: &ClusterSnapshot,
        _store: &StateStore,
    ) -> Vec<Decision> {
        self.decisions += batch.len() as u64;
        batch
            .iter()
            .map(|req| Decision {
                // Ceil like resource quantities round up in K8s manifests;
                // the scheduler enforces node fit, the engine retries.
                cpu_milli: (req.req_cpu * self.headroom).ceil() as i64,
                mem_mi: (req.req_mem * self.headroom).ceil() as i64,
                request_cpu: req.req_cpu * self.headroom,
                request_mem: req.req_mem * self.headroom,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ResidualMap;

    fn req() -> TaskRequest {
        TaskRequest {
            task_id: "t".into(),
            req_cpu: 2000.0,
            req_mem: 4000.0,
            min_cpu: 200.0,
            min_mem: 1000.0,
            win_start: 0.0,
            win_end: 15.0,
        }
    }

    #[test]
    fn scales_requests_up_by_the_factor() {
        let mut p = StaticHeadroomPolicy::new(1.2).unwrap();
        let snap = ClusterSnapshot::from_residuals(ResidualMap::default());
        let d = p.plan(&[req()], &snap, &StateStore::new())[0];
        assert_eq!(d.cpu_milli, 2400);
        assert_eq!(d.mem_mi, 4800);
        assert!(d.meets_minimum(200.0, 1000.0, 20.0));
    }

    #[test]
    fn rejects_shrinking_factors() {
        assert!(StaticHeadroomPolicy::new(0.9).is_err());
        assert!(StaticHeadroomPolicy::new(f64::NAN).is_err());
        assert!(StaticHeadroomPolicy::new(1.0).is_ok());
    }
}
