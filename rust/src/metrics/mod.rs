//! Metrics collection: usage timeseries (Figs 5–8), event log (Figs 1, 9),
//! and the run summary behind Table 2's rows.

use crate::obs::quantile::Histogram;
use crate::obs::PhaseBreakdown;
use crate::simcore::SimTime;
use std::collections::HashSet;
use std::sync::Arc;

/// One resource-usage sample across the cluster.
#[derive(Debug, Clone, Copy)]
pub struct UsageSample {
    pub t: SimTime,
    /// Requested CPU currently held by live pods (milli-cores).
    pub cpu_used: f64,
    /// Requested memory currently held by live pods (Mi).
    pub mem_used: f64,
    /// cpu_used / cluster allocatable.
    pub cpu_rate: f64,
    /// mem_used / cluster allocatable.
    pub mem_rate: f64,
    pub running_pods: usize,
    /// Nodes present in the cluster at sample time (the node-count
    /// timeseries; constant for static runs, a step curve under churn
    /// and autoscaling).
    pub nodes: usize,
}

/// Engine event kinds (the structured log Figs 1 and 9 are cut from).
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    WorkflowInjected,
    TaskRequested,
    AllocDecided { cpu_milli: i64, mem_mi: i64 },
    AllocWait { reason: String },
    PodCreated,
    PodRunning,
    PodSucceeded,
    PodOomKilled,
    PodDeleted,
    TaskReallocated,
    WorkflowCompleted,
    /// A node joined the cluster (initial pools are not logged; this is
    /// scheduled joins and autoscaler scale-ups).
    NodeJoined { node: String },
    /// A node was cordoned and its pods are being evicted gracefully.
    NodeDraining { node: String },
    /// A node crashed: removed immediately, pods killed.
    NodeCrashed { node: String },
    /// A node left the cluster (drain completed, or crash).
    NodeRemoved { node: String },
    /// A pod was evicted by a drain (`drain == true`) or killed by a
    /// crash (`drain == false`); its task re-enters the allocation queue
    /// after cleanup.
    PodEvicted { node: String, drain: bool },
}

impl EventKind {
    /// Stable wire name + human-readable detail, shared by the timeline
    /// CSV and the `--trace-out` journal.
    pub fn name_and_detail(&self) -> (&'static str, String) {
        match self {
            EventKind::WorkflowInjected => ("WorkflowInjected", String::new()),
            EventKind::TaskRequested => ("TaskRequested", String::new()),
            EventKind::AllocDecided { cpu_milli, mem_mi } => {
                ("AllocDecided", format!("cpu={cpu_milli}m mem={mem_mi}Mi"))
            }
            EventKind::AllocWait { reason } => ("AllocWait", reason.clone()),
            EventKind::PodCreated => ("PodCreated", String::new()),
            EventKind::PodRunning => ("PodRunning", String::new()),
            EventKind::PodSucceeded => ("PodSucceeded", String::new()),
            EventKind::PodOomKilled => ("OOMKilled", String::new()),
            EventKind::PodDeleted => ("PodDeleted", String::new()),
            EventKind::TaskReallocated => ("Reallocation", String::new()),
            EventKind::WorkflowCompleted => ("WorkflowCompleted", String::new()),
            EventKind::NodeJoined { node } => ("NodeJoined", node.clone()),
            EventKind::NodeDraining { node } => ("NodeDraining", node.clone()),
            EventKind::NodeCrashed { node } => ("NodeCrashed", node.clone()),
            EventKind::NodeRemoved { node } => ("NodeRemoved", node.clone()),
            EventKind::PodEvicted { node, drain } => (
                "PodEvicted",
                format!("{} ({})", node, if *drain { "drain" } else { "crash" }),
            ),
        }
    }
}

#[derive(Debug, Clone)]
pub struct LogEvent {
    pub t: SimTime,
    pub workflow_uid: u64,
    /// Interned: repeated ids for the same task share one allocation
    /// (a task logs 5–8 lifecycle events on a normal run).
    pub task_id: Arc<str>,
    pub kind: EventKind,
}

/// One scored forecast: the engine's one-tick-ahead demand prediction
/// against the demand that materialized at the target tick. Feeds the
/// MAPE/RMSE columns of [`RunSummary`].
#[derive(Debug, Clone, Copy)]
pub struct ForecastPoint {
    pub pred_cpu: f64,
    pub actual_cpu: f64,
    pub pred_mem: f64,
    pub actual_mem: f64,
}

/// Mean absolute percentage error (%), over points with non-zero actuals
/// (a percentage error against zero demand is undefined; such ticks are
/// skipped, not counted as perfect).
fn mape(points: &[ForecastPoint], pick: impl Fn(&ForecastPoint) -> (f64, f64)) -> f64 {
    let errs: Vec<f64> = points
        .iter()
        .map(pick)
        .filter(|&(_, actual)| actual > 0.0)
        .map(|(pred, actual)| ((pred - actual) / actual).abs() * 100.0)
        .collect();
    crate::util::stats::mean(&errs)
}

/// Root-mean-square error, in the series' own unit.
fn rmse(points: &[ForecastPoint], pick: impl Fn(&ForecastPoint) -> (f64, f64)) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    let sq: Vec<f64> = points
        .iter()
        .map(pick)
        .map(|(pred, actual)| (pred - actual) * (pred - actual))
        .collect();
    crate::util::stats::mean(&sq).sqrt()
}

/// Per-submission latency accounting for daemon-mode ingest: one record
/// per completed submission (a `submit` command or one schedule-source
/// occurrence). Kept beside [`RunSummary`] — not inside it — so the
/// daemon's determinism bridge can compare summaries bit-exactly against
/// batch runs, which have no submissions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubmissionRecord {
    /// Submission id (engine-assigned, in arrival order).
    pub id: u64,
    /// Virtual time the submission asked to run at.
    pub submitted_for: SimTime,
    /// Virtual time its workflows were injected (>= submitted_for).
    pub injected_at: SimTime,
    /// Virtual time the last of its workflows completed.
    pub completed_at: SimTime,
    /// Workflows in the submission.
    pub workflows: usize,
}

impl SubmissionRecord {
    /// Injection → last-completion latency (virtual seconds).
    pub fn latency_s(&self) -> f64 {
        self.completed_at - self.injected_at
    }
}

/// Aggregated results of one run (one Table 2 cell set).
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// Elapsed time from first request arrival to last workflow
    /// completion, minutes ("Total Duration of All Workflows").
    pub total_duration_min: f64,
    /// Mean per-workflow duration, minutes ("Average Workflow Duration").
    pub avg_workflow_duration_min: f64,
    /// Time-averaged CPU usage rate over the total duration.
    pub cpu_usage: f64,
    /// Time-averaged memory usage rate.
    pub mem_usage: f64,
    pub workflows_completed: usize,
    pub tasks_completed: usize,
    pub oom_events: usize,
    pub alloc_waits: usize,
    /// Workflows that finished after their SLA deadline (0 when the
    /// workload assigns no deadlines).
    pub sla_violations: usize,
    /// Pods evicted by node drains or crashes (0 on static clusters).
    pub evictions: usize,
    /// Nodes that joined mid-run (scheduled joins + autoscaler).
    pub nodes_joined: usize,
    /// Nodes that left mid-run (drains + crashes).
    pub nodes_removed: usize,
    /// Scored one-tick-ahead forecasts (0 when no forecaster ran — the
    /// accuracy fields below are then all 0 too).
    pub forecast_points: usize,
    /// Forecast accuracy per resource: mean absolute percentage error.
    pub forecast_mape_cpu: f64,
    pub forecast_mape_mem: f64,
    /// Forecast accuracy per resource: root-mean-square error
    /// (milli-cores / Mi).
    pub forecast_rmse_cpu: f64,
    pub forecast_rmse_mem: f64,
    /// CPU stolen by chaos hogs, integrated over time (milli-core ×
    /// seconds; 0 when no chaos ran).
    pub hog_stolen_cpu_s: f64,
    /// Memory stolen by chaos hogs, integrated over time (Mi × seconds).
    pub hog_stolen_mem_s: f64,
    /// Queue-serve cycles planned against a stale snapshot (informer
    /// partition or latency storm suppressed the sync).
    pub stale_snapshot_cycles: usize,
    /// Launch attempts that passed planning on a stale snapshot but
    /// failed ground-truth scheduling — the double-allocation risk the
    /// partition scenarios exist to expose.
    pub double_alloc_attempts: usize,
    /// Workflow-duration quantiles (seconds) from the constant-memory
    /// streaming histogram — exact for runs within the buffer, P²
    /// estimates beyond. Replaces stored-sample percentile math.
    pub wf_duration_p50_s: f64,
    pub wf_duration_p95_s: f64,
    /// Per-phase span counts (deterministic) and wall-clock
    /// nanoseconds (0 unless wall timing was opted into, e.g. `bench`).
    pub phases: PhaseBreakdown,
}

/// Collects everything during a run.
#[derive(Debug, Default)]
pub struct Collector {
    pub samples: Vec<UsageSample>,
    pub events: Vec<LogEvent>,
    /// (time, cumulative workflow requests) step curve (Figs 5–8 top).
    pub arrivals: Vec<(SimTime, usize)>,
    /// Completed workflow durations (seconds).
    pub wf_durations: Vec<f64>,
    pub makespan_s: f64,
    pub tasks_completed: usize,
    pub sla_violations: usize,
    /// Scored forecasts (empty when no forecaster ran).
    pub forecast_points: Vec<ForecastPoint>,
    /// Chaos accounting, set by the engine before summarize (all zero
    /// when no chaos ran).
    pub hog_stolen_cpu_s: f64,
    pub hog_stolen_mem_s: f64,
    pub stale_snapshot_cycles: usize,
    pub double_alloc_attempts: usize,
    /// Completed daemon-mode submissions (empty for batch runs — the
    /// determinism bridge relies on this staying out of [`RunSummary`]).
    pub submissions: Vec<SubmissionRecord>,
    /// Streaming workflow-duration distribution, fed in lockstep with
    /// `wf_durations` by [`Collector::workflow_completed`].
    pub wf_duration_hist: Histogram,
    /// Per-phase span totals, copied from the engine's recorder before
    /// summarize (all zero for hand-built collectors).
    pub phase_breakdown: PhaseBreakdown,
    /// Task-id string interner backing [`LogEvent::task_id`].
    interned: HashSet<Arc<str>>,
}

impl Collector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn log(&mut self, t: SimTime, workflow_uid: u64, task_id: &str, kind: EventKind) {
        let task_id = match self.interned.get(task_id) {
            Some(s) => Arc::clone(s),
            None => {
                let s: Arc<str> = Arc::from(task_id);
                self.interned.insert(Arc::clone(&s));
                s
            }
        };
        self.events.push(LogEvent { t, workflow_uid, task_id, kind });
    }

    /// Record one completed workflow's duration (seconds): the stored
    /// series (mean, reports) and the streaming histogram (quantiles)
    /// stay in lockstep.
    pub fn workflow_completed(&mut self, duration_s: f64) {
        self.wf_durations.push(duration_s);
        self.wf_duration_hist.observe(duration_s);
    }

    pub fn sample(&mut self, s: UsageSample) {
        self.samples.push(s);
    }

    pub fn arrival(&mut self, t: SimTime, cumulative: usize) {
        self.arrivals.push((t, cumulative));
    }

    pub fn count(&self, pred: impl Fn(&EventKind) -> bool) -> usize {
        self.events.iter().filter(|e| pred(&e.kind)).count()
    }

    /// Time-weighted mean of a rate column over [0, makespan].
    fn time_weighted_rate(&self, pick: impl Fn(&UsageSample) -> f64) -> f64 {
        if self.samples.len() < 2 {
            return self.samples.first().map(&pick).unwrap_or(0.0);
        }
        let mut area = 0.0;
        for w in self.samples.windows(2) {
            let dt = w[1].t - w[0].t;
            area += 0.5 * (pick(&w[0]) + pick(&w[1])) * dt;
        }
        let span = self.samples.last().unwrap().t - self.samples[0].t;
        if span > 0.0 {
            area / span
        } else {
            0.0
        }
    }

    pub fn summarize(&self) -> RunSummary {
        RunSummary {
            total_duration_min: self.makespan_s / 60.0,
            avg_workflow_duration_min: crate::util::stats::mean(&self.wf_durations) / 60.0,
            cpu_usage: self.time_weighted_rate(|s| s.cpu_rate),
            mem_usage: self.time_weighted_rate(|s| s.mem_rate),
            workflows_completed: self.wf_durations.len(),
            tasks_completed: self.tasks_completed,
            oom_events: self.count(|k| matches!(k, EventKind::PodOomKilled)),
            alloc_waits: self.count(|k| matches!(k, EventKind::AllocWait { .. })),
            sla_violations: self.sla_violations,
            evictions: self.count(|k| matches!(k, EventKind::PodEvicted { .. })),
            nodes_joined: self.count(|k| matches!(k, EventKind::NodeJoined { .. })),
            nodes_removed: self.count(|k| matches!(k, EventKind::NodeRemoved { .. })),
            forecast_points: self.forecast_points.len(),
            forecast_mape_cpu: mape(&self.forecast_points, |p| (p.pred_cpu, p.actual_cpu)),
            forecast_mape_mem: mape(&self.forecast_points, |p| (p.pred_mem, p.actual_mem)),
            forecast_rmse_cpu: rmse(&self.forecast_points, |p| (p.pred_cpu, p.actual_cpu)),
            forecast_rmse_mem: rmse(&self.forecast_points, |p| (p.pred_mem, p.actual_mem)),
            hog_stolen_cpu_s: self.hog_stolen_cpu_s,
            hog_stolen_mem_s: self.hog_stolen_mem_s,
            stale_snapshot_cycles: self.stale_snapshot_cycles,
            double_alloc_attempts: self.double_alloc_attempts,
            wf_duration_p50_s: self.wf_duration_hist.quantile(0.50),
            wf_duration_p95_s: self.wf_duration_hist.quantile(0.95),
            phases: self.phase_breakdown,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_weighted_rate_is_trapezoidal() {
        let mut c = Collector::new();
        for (t, r) in [(0.0, 0.0), (10.0, 1.0), (20.0, 1.0)] {
            c.sample(UsageSample {
                t,
                cpu_used: 0.0,
                mem_used: 0.0,
                cpu_rate: r,
                mem_rate: r,
                running_pods: 0,
                nodes: 6,
            });
        }
        // area = 0.5*1*10 + 1*10 = 15 over span 20 => 0.75
        let s = c.summarize();
        assert!((s.cpu_usage - 0.75).abs() < 1e-12);
    }

    #[test]
    fn summary_counts_events() {
        let mut c = Collector::new();
        c.log(1.0, 1, "t1", EventKind::PodOomKilled);
        c.log(2.0, 1, "t1", EventKind::AllocWait { reason: "below-min".into() });
        c.wf_durations.push(120.0);
        c.makespan_s = 600.0;
        c.tasks_completed = 21;
        let s = c.summarize();
        assert_eq!(s.oom_events, 1);
        assert_eq!(s.alloc_waits, 1);
        assert_eq!(s.total_duration_min, 10.0);
        assert_eq!(s.avg_workflow_duration_min, 2.0);
    }

    #[test]
    fn empty_collector_is_safe() {
        let s = Collector::new().summarize();
        assert_eq!(s.cpu_usage, 0.0);
        assert_eq!(s.workflows_completed, 0);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.nodes_joined, 0);
        assert_eq!(s.nodes_removed, 0);
        assert_eq!(s.forecast_points, 0);
        assert_eq!(s.forecast_mape_cpu, 0.0);
        assert_eq!(s.forecast_rmse_mem, 0.0);
        assert_eq!(s.hog_stolen_cpu_s, 0.0);
        assert_eq!(s.hog_stolen_mem_s, 0.0);
        assert_eq!(s.stale_snapshot_cycles, 0);
        assert_eq!(s.double_alloc_attempts, 0);
    }

    #[test]
    fn forecast_accuracy_is_mape_and_rmse() {
        let mut c = Collector::new();
        c.forecast_points.push(ForecastPoint {
            pred_cpu: 110.0,
            actual_cpu: 100.0,
            pred_mem: 250.0,
            actual_mem: 200.0,
        });
        c.forecast_points.push(ForecastPoint {
            pred_cpu: 90.0,
            actual_cpu: 100.0,
            pred_mem: 150.0,
            actual_mem: 200.0,
        });
        // A zero-demand tick: excluded from MAPE, included in RMSE.
        c.forecast_points.push(ForecastPoint {
            pred_cpu: 0.0,
            actual_cpu: 0.0,
            pred_mem: 0.0,
            actual_mem: 0.0,
        });
        let s = c.summarize();
        assert_eq!(s.forecast_points, 3);
        assert!((s.forecast_mape_cpu - 10.0).abs() < 1e-12, "{}", s.forecast_mape_cpu);
        assert!((s.forecast_mape_mem - 25.0).abs() < 1e-12, "{}", s.forecast_mape_mem);
        // RMSE over all three: sqrt((100 + 100 + 0) / 3).
        let want = (200.0f64 / 3.0).sqrt();
        assert!((s.forecast_rmse_cpu - want).abs() < 1e-12);
    }

    #[test]
    fn task_ids_are_interned() {
        let mut c = Collector::new();
        for t in 0..4 {
            c.log(t as f64, 1, "wf1-task7", EventKind::PodRunning);
        }
        c.log(4.0, 2, "wf2-task1", EventKind::PodRunning);
        // Same id => same allocation; different id => different one.
        assert!(Arc::ptr_eq(&c.events[0].task_id, &c.events[3].task_id));
        assert!(!Arc::ptr_eq(&c.events[0].task_id, &c.events[4].task_id));
        assert_eq!(&*c.events[3].task_id, "wf1-task7");
    }

    #[test]
    fn workflow_completed_feeds_hist_and_series_in_lockstep() {
        let mut c = Collector::new();
        for d in [120.0, 60.0, 240.0, 180.0] {
            c.workflow_completed(d);
        }
        c.makespan_s = 600.0;
        let s = c.summarize();
        assert_eq!(s.workflows_completed, 4);
        // Small run => streaming quantiles are bit-exact vs stored-sample math.
        assert_eq!(
            s.wf_duration_p50_s.to_bits(),
            crate::util::stats::percentile(&c.wf_durations, 50.0).to_bits()
        );
        assert_eq!(
            s.wf_duration_p95_s.to_bits(),
            crate::util::stats::percentile(&c.wf_durations, 95.0).to_bits()
        );
    }

    #[test]
    fn summary_counts_cluster_lifecycle_events() {
        let mut c = Collector::new();
        c.log(1.0, 0, "", EventKind::NodeJoined { node: "node-6".into() });
        c.log(2.0, 0, "", EventKind::NodeDraining { node: "node-3".into() });
        c.log(2.0, 1, "wf1-t2", EventKind::PodEvicted { node: "node-3".into(), drain: true });
        c.log(3.0, 1, "wf1-t4", EventKind::PodEvicted { node: "node-0".into(), drain: false });
        c.log(4.0, 0, "", EventKind::NodeRemoved { node: "node-3".into() });
        let s = c.summarize();
        assert_eq!(s.evictions, 2);
        assert_eq!(s.nodes_joined, 1);
        assert_eq!(s.nodes_removed, 1);
    }
}
