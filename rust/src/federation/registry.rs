//! The open router registry — the federation twin of
//! [`crate::resources::registry`] and
//! [`crate::forecast::registry`]: string names (plus aliases) map to
//! factory closures that turn a [`RouterSpec`] (name + numeric params,
//! carried by `config::FederationConfig`) into a boxed [`Router`]. The
//! process-wide registry starts with the four built-ins
//! (`round-robin`, `least-queue`, `forecast-headroom`, `weighted`);
//! mounting a new strategy is one call:
//!
//! ```
//! use kubeadaptor::federation::{registry, RoundRobinRouter};
//!
//! registry::register_router("my-gateway", &[], "always cluster 0", |_spec| {
//!     Ok(Box::new(RoundRobinRouter::new()))
//! })
//! .unwrap();
//! // From here `--router my-gateway`, config files and the federate
//! // experiment all resolve it.
//! ```
//!
//! Unknown names fail when the federation runner is built, with the
//! roster; unknown params fail inside the factory (each built-in
//! validates its accepted keys).
//!
//! **Aliases are an input convenience, not an identity** (same rule as
//! the policy and forecaster registries): report grouping compares
//! [`RouterSpec`] values, and the built-in aliases (`rr`, `lq`,
//! `headroom`, `wrr`) are canonicalized in
//! [`RouterSpec::named`]/`parse` — kept in lockstep with the alias
//! lists below.

use std::sync::{OnceLock, RwLock};

use super::router::{
    ForecastHeadroomRouter, LeastQueueRouter, RoundRobinRouter, Router, WeightedRouter,
};

pub use crate::config::RouterSpec;

/// Factory signature: the parsed spec (name + params).
pub type RouterFactory =
    Box<dyn Fn(&RouterSpec) -> anyhow::Result<Box<dyn Router>> + Send + Sync>;

/// One registered routing strategy.
pub struct RouterEntry {
    pub name: String,
    pub aliases: Vec<String>,
    /// One-line description for `--list-routers`.
    pub summary: String,
    factory: RouterFactory,
}

impl RouterEntry {
    fn matches(&self, name: &str) -> bool {
        self.name.eq_ignore_ascii_case(name)
            || self.aliases.iter().any(|a| a.eq_ignore_ascii_case(name))
    }
}

/// String-keyed router registry.
#[derive(Default)]
pub struct RouterRegistry {
    entries: Vec<RouterEntry>,
}

impl RouterRegistry {
    /// An empty registry (library embedders composing their own set).
    pub fn empty() -> Self {
        Self::default()
    }

    /// A registry pre-loaded with the four built-in routers.
    pub fn with_builtins() -> Self {
        let mut r = Self::empty();
        r.register(
            "round-robin",
            &["rr"],
            "cycle clusters in federation order (no params)",
            |spec| {
                check_params(spec, &[])?;
                Ok(Box::new(RoundRobinRouter::new()))
            },
        )
        .expect("builtin registration");
        r.register(
            "least-queue",
            &["lq"],
            "shallowest allocation queue first (no params)",
            |spec| {
                check_params(spec, &[])?;
                Ok(Box::new(LeastQueueRouter::new()))
            },
        )
        .expect("builtin registration");
        r.register(
            "forecast-headroom",
            &["headroom"],
            "largest forecast-adjusted residual headroom first [params: margin]",
            |spec| {
                check_params(spec, &["margin"])?;
                let margin = spec.param("margin").unwrap_or(0.0);
                Ok(Box::new(ForecastHeadroomRouter::new(margin)?))
            },
        )
        .expect("builtin registration");
        r.register(
            "weighted",
            &["wrr"],
            "smooth weighted round-robin over cluster weights (no params)",
            |spec| {
                check_params(spec, &[])?;
                Ok(Box::new(WeightedRouter::new()))
            },
        )
        .expect("builtin registration");
        r
    }

    /// Mount a router: `name` (and each alias) must not collide with an
    /// existing entry.
    pub fn register(
        &mut self,
        name: impl Into<String>,
        aliases: &[&str],
        summary: impl Into<String>,
        factory: impl Fn(&RouterSpec) -> anyhow::Result<Box<dyn Router>> + Send + Sync + 'static,
    ) -> anyhow::Result<()> {
        let name = name.into().to_lowercase();
        anyhow::ensure!(!name.is_empty(), "router name must be non-empty");
        for candidate in std::iter::once(name.as_str()).chain(aliases.iter().copied()) {
            anyhow::ensure!(
                self.resolve(candidate).is_none(),
                "router name '{candidate}' is already registered"
            );
        }
        self.entries.push(RouterEntry {
            name,
            aliases: aliases.iter().map(|a| a.to_lowercase()).collect(),
            summary: summary.into(),
            factory: Box::new(factory),
        });
        Ok(())
    }

    /// Look an entry up by name or alias (case-insensitive).
    pub fn resolve(&self, name: &str) -> Option<&RouterEntry> {
        self.entries.iter().find(|e| e.matches(name))
    }

    /// Canonical name for a spelling (alias → primary name).
    pub fn canonical_name(&self, name: &str) -> Option<&str> {
        self.resolve(name).map(|e| e.name.as_str())
    }

    /// Instantiate the router a spec describes.
    pub fn build(&self, spec: &RouterSpec) -> anyhow::Result<Box<dyn Router>> {
        let entry = self.resolve(&spec.name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown router '{}' (registered: {})",
                spec.name,
                self.names().join(", ")
            )
        })?;
        (entry.factory)(spec).map_err(|e| anyhow::anyhow!("building router '{}': {e}", entry.name))
    }

    /// Registered canonical names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    /// (name, aliases, summary) rows for `--list-routers`, sorted by
    /// name so the roster prints deterministically regardless of
    /// registration order.
    pub fn listing(&self) -> Vec<(String, Vec<String>, String)> {
        let mut rows: Vec<(String, Vec<String>, String)> = self
            .entries
            .iter()
            .map(|e| (e.name.clone(), e.aliases.clone(), e.summary.clone()))
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }

    pub fn entries(&self) -> &[RouterEntry] {
        &self.entries
    }
}

// ------------------------------------------------------- global registry

static GLOBAL: OnceLock<RwLock<RouterRegistry>> = OnceLock::new();

/// The process-wide registry (built-ins pre-registered on first use).
pub fn global() -> &'static RwLock<RouterRegistry> {
    GLOBAL.get_or_init(|| RwLock::new(RouterRegistry::with_builtins()))
}

/// Mount a router into the global registry.
pub fn register_router(
    name: impl Into<String>,
    aliases: &[&str],
    summary: impl Into<String>,
    factory: impl Fn(&RouterSpec) -> anyhow::Result<Box<dyn Router>> + Send + Sync + 'static,
) -> anyhow::Result<()> {
    global().write().unwrap().register(name, aliases, summary, factory)
}

/// Instantiate `spec` via the global registry.
pub fn build_router(spec: &RouterSpec) -> anyhow::Result<Box<dyn Router>> {
    global().read().unwrap().build(spec)
}

/// Canonical names registered globally, in registration order.
pub fn router_names() -> Vec<String> {
    global().read().unwrap().names()
}

/// Sorted (name, aliases, summary) rows for `--list-routers`.
pub fn router_listing() -> Vec<(String, Vec<String>, String)> {
    global().read().unwrap().listing()
}

/// Reject params a router does not understand (typo protection).
fn check_params(spec: &RouterSpec, allowed: &[&str]) -> anyhow::Result<()> {
    for (key, _) in &spec.params {
        anyhow::ensure!(
            allowed.contains(&key.as_str()),
            "router '{}' has no parameter '{}'{}",
            spec.name,
            key,
            if allowed.is_empty() {
                " (it takes none)".to_string()
            } else {
                format!(" (accepted: {})", allowed.join(", "))
            }
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_by_name_and_alias() {
        let r = RouterRegistry::with_builtins();
        assert_eq!(r.names(), vec!["round-robin", "least-queue", "forecast-headroom", "weighted"]);
        assert_eq!(r.canonical_name("RR"), Some("round-robin"));
        assert_eq!(r.canonical_name("lq"), Some("least-queue"));
        assert_eq!(r.canonical_name("headroom"), Some("forecast-headroom"));
        assert_eq!(r.canonical_name("wrr"), Some("weighted"));
        assert!(r.resolve("nope").is_none());
    }

    #[test]
    fn listing_is_sorted_regardless_of_registration_order() {
        let mut r = RouterRegistry::with_builtins();
        // Registered last, sorts first.
        r.register("aaa-gateway", &[], "test", |_s| Ok(Box::new(RoundRobinRouter::new())))
            .unwrap();
        let names: Vec<&str> = r.listing().iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec!["aaa-gateway", "forecast-headroom", "least-queue", "round-robin", "weighted"]
        );
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn build_reports_unknown_names_with_the_roster() {
        let r = RouterRegistry::with_builtins();
        let err = r.build(&RouterSpec::named("nope")).unwrap_err().to_string();
        assert!(err.contains("unknown router 'nope'"), "{err}");
        assert!(err.contains("forecast-headroom"), "{err}");
    }

    #[test]
    fn unknown_params_are_rejected() {
        let r = RouterRegistry::with_builtins();
        let err = r
            .build(&RouterSpec::named("round-robin").with_param("zeal", 9.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("no parameter 'zeal'"), "{err}");
        assert!(err.contains("it takes none"), "{err}");
        let err = r
            .build(&RouterSpec::named("forecast-headroom").with_param("warp", 1.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("accepted: margin"), "{err}");
    }

    #[test]
    fn params_flow_into_factories() {
        let r = RouterRegistry::with_builtins();
        assert!(r.build(&RouterSpec::named("forecast-headroom").with_param("margin", 0.1)).is_ok());
        assert!(r
            .build(&RouterSpec::named("forecast-headroom").with_param("margin", -0.5))
            .is_err());
    }

    #[test]
    fn duplicate_registration_is_rejected() {
        let mut r = RouterRegistry::with_builtins();
        let err = r
            .register("wrr", &[], "dup", |_s| Ok(Box::new(RoundRobinRouter::new())))
            .unwrap_err()
            .to_string();
        assert!(err.contains("already registered"), "{err}");
    }
}
