//! Multi-cluster federation: N independent simulated clusters — each
//! with its own [`Engine`], state store, policy, forecaster, autoscaler,
//! churn and chaos profile — advancing under one shared virtual clock,
//! with a global [`Router`] placing each arriving workflow on the
//! cluster its strategy prefers.
//!
//! ## The shared clock
//!
//! Arrivals stream from [`crate::workload::plan_iter`] (the base
//! config's workload — every member cluster sees the same workflow
//! template, so router comparisons are workload-paired). Before each
//! routing decision every engine is advanced to the arrival instant
//! with [`Engine::run_until`]; the router then scores *synchronized*
//! cluster states, exactly like a real federation gateway sampling
//! member apiservers at admission time.
//!
//! ## Spillover
//!
//! The router returns a full preference ranking, not a single winner.
//! The runner walks it and places on the first cluster that is not
//! overloaded — overloaded meaning a deep allocation queue
//! (`spill_queue_depth`), a spiking stale-snapshot rate
//! (`spill_stale_rate`, the partition/latency-storm signal), or no
//! live nodes at all (a regional outage). Placements that skip the
//! first choice are counted as spillovers, per receiving cluster.
//!
//! ## Determinism
//!
//! Per-cluster engine seeds derive from the base workload seed via
//! [`derive_seed`]`(base, [FED_SEED_STREAM, index])` — decorrelated
//! across members, bit-stable across runs and thread counts. Routers
//! are deterministic state machines and the submission stream is
//! sequential, so a federation run is bit-reproducible; the
//! `federation` golden scenario locks it and
//! [`run_many`] parallelizes only across whole federations (engines
//! never cross threads).

pub mod registry;
pub mod router;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::config::{ExperimentConfig, FederationConfig, RouterSpec};
use crate::engine::{Engine, RunOutcome};
use crate::metrics::{Collector, RunSummary};
use crate::obs::expo::TextExposition;
use crate::obs::PhaseBreakdown;
use crate::simcore::derive_seed;
use crate::workload;

pub use router::{
    ForecastHeadroomRouter, LeastQueueRouter, RoundRobinRouter, RouteInput, Router, WeightedRouter,
};

/// Seed-stream tag separating per-cluster engine seeds from every other
/// consumer of the base workload seed (campaign coordinates, trace
/// replay, …).
pub const FED_SEED_STREAM: u64 = 0xFED;

/// One fully-specified federation run: a label, the base experiment
/// config (workload, timing, task shape — everything member clusters
/// inherit) and the federation block (members + router + spill knobs).
/// `base.federation` is ignored; the explicit block wins.
#[derive(Debug, Clone)]
pub struct FederationSpec {
    pub name: String,
    pub base: ExperimentConfig,
    pub federation: FederationConfig,
}

impl FederationSpec {
    /// Build a spec from a config whose `federation` block is set.
    pub fn from_config(name: impl Into<String>, cfg: &ExperimentConfig) -> anyhow::Result<Self> {
        let federation = cfg
            .federation
            .clone()
            .ok_or_else(|| anyhow::anyhow!("config has no 'federation' block"))?;
        Ok(Self { name: name.into(), base: cfg.clone(), federation })
    }
}

/// Per-cluster slice of a [`FederatedSummary`].
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub name: String,
    /// Initial node count after the overlay.
    pub nodes: usize,
    pub weight: f64,
    /// Times the router ranked this cluster first.
    pub first_choice: usize,
    /// Workflows actually placed here.
    pub placements: usize,
    /// Placements that arrived via spillover (first choice was another,
    /// overloaded cluster).
    pub spill_in: usize,
    pub workflows_completed: usize,
    pub tasks_completed: usize,
    pub total_duration_min: f64,
    pub avg_workflow_duration_min: f64,
    pub cpu_usage: f64,
    pub mem_usage: f64,
    pub alloc_waits: usize,
    pub evictions: usize,
    pub stale_snapshot_cycles: usize,
}

/// Cross-cluster fold of one federation run: per-cluster reports plus
/// placement/spillover accounting and federation-level aggregates.
#[derive(Debug, Clone)]
pub struct FederatedSummary {
    /// Router label (`name` or `name:k=v,…`).
    pub router: String,
    pub clusters: Vec<ClusterReport>,
    /// Total routing decisions (= workflows submitted).
    pub routed: usize,
    /// Decisions diverted off the first-choice cluster.
    pub spillovers: usize,
    pub workflows_completed: usize,
    pub tasks_completed: usize,
    /// Federation makespan: the max over member clusters (all share one
    /// clock starting at 0).
    pub total_duration_min: f64,
    /// Completion-weighted mean workflow duration.
    pub avg_workflow_duration_min: f64,
    /// Node-weighted mean utilizations.
    pub cpu_usage: f64,
    pub mem_usage: f64,
}

impl FederatedSummary {
    /// Render the federation's cross-cluster accounting as a Prometheus
    /// text exposition: router decision counters plus per-cluster
    /// `ka_fed_*` series labeled by cluster name.
    pub fn prometheus_metrics(&self) -> String {
        let mut e = TextExposition::new();
        e.counter(
            "ka_fed_routed_total",
            "Workflows placed by the global router.",
            self.routed as f64,
        );
        e.counter(
            "ka_fed_spillovers_total",
            "Routing decisions diverted off the first-choice cluster.",
            self.spillovers as f64,
        );
        e.gauge("ka_fed_clusters", "Member clusters in the federation.", self.clusters.len() as f64);
        let series = |pick: fn(&ClusterReport) -> f64| -> Vec<(&str, f64)> {
            self.clusters.iter().map(|c| (c.name.as_str(), pick(c))).collect()
        };
        e.counter_vec(
            "ka_fed_first_choice_total",
            "Times the router ranked a cluster first.",
            "cluster",
            &series(|c| c.first_choice as f64),
        );
        e.counter_vec(
            "ka_fed_placements_total",
            "Workflows placed per cluster.",
            "cluster",
            &series(|c| c.placements as f64),
        );
        e.counter_vec(
            "ka_fed_spill_in_total",
            "Workflows arriving via spillover.",
            "cluster",
            &series(|c| c.spill_in as f64),
        );
        e.counter_vec(
            "ka_fed_workflows_completed_total",
            "Workflows completed per cluster.",
            "cluster",
            &series(|c| c.workflows_completed as f64),
        );
        e.counter_vec(
            "ka_fed_tasks_completed_total",
            "Tasks completed per cluster.",
            "cluster",
            &series(|c| c.tasks_completed as f64),
        );
        e.counter_vec(
            "ka_fed_alloc_waits_total",
            "Allocation waits per cluster.",
            "cluster",
            &series(|c| c.alloc_waits as f64),
        );
        e.counter_vec(
            "ka_fed_stale_snapshot_cycles_total",
            "Stale serve cycles per cluster.",
            "cluster",
            &series(|c| c.stale_snapshot_cycles as f64),
        );
        e.gauge_vec(
            "ka_fed_cluster_nodes",
            "Initial nodes per cluster.",
            "cluster",
            &series(|c| c.nodes as f64),
        );
        e.gauge_vec(
            "ka_fed_cluster_cpu_usage",
            "Mean CPU utilization per cluster.",
            "cluster",
            &series(|c| c.cpu_usage),
        );
        e.gauge_vec(
            "ka_fed_cluster_mem_usage",
            "Mean memory utilization per cluster.",
            "cluster",
            &series(|c| c.mem_usage),
        );
        e.render()
    }
}

/// Everything a federation run produced: the fold plus each member
/// cluster's full [`RunOutcome`] (federation order).
pub struct FederationResult {
    pub summary: FederatedSummary,
    pub outcomes: Vec<RunOutcome>,
}

fn is_permutation(order: &[usize], n: usize) -> bool {
    if order.len() != n {
        return false;
    }
    let mut seen = vec![false; n];
    for &i in order {
        if i >= n || seen[i] {
            return false;
        }
        seen[i] = true;
    }
    true
}

/// Run one federation to completion. Sequential and bit-deterministic:
/// the only parallelism in this subsystem is *across* federations
/// ([`run_many`]), never within one.
pub fn run_spec(spec: &FederationSpec) -> anyhow::Result<FederationResult> {
    let fed = &spec.federation;
    fed.validate()?;
    let n = fed.clusters.len();
    let mut router = registry::build_router(&fed.router)?;

    // Materialize and start every member engine. Per-cluster seeds are
    // derived, not shared: member clusters must not replay each other's
    // internal randomness.
    let mut engines: Vec<Engine> = Vec::with_capacity(n);
    let mut nodes = Vec::with_capacity(n);
    for (i, cs) in fed.clusters.iter().enumerate() {
        let mut cfg = cs.apply(&spec.base);
        cfg.workload.seed = derive_seed(spec.base.workload.seed, &[FED_SEED_STREAM, i as u64]);
        nodes.push(cfg.cluster.initial_nodes());
        let mut engine = Engine::serving(cfg)
            .map_err(|e| anyhow::anyhow!("federation cluster '{}': {e}", cs.name))?;
        engine.start();
        engines.push(engine);
    }

    let mut first_choice = vec![0usize; n];
    let mut placements = vec![0usize; n];
    let mut spill_in = vec![0usize; n];
    let mut routed = 0usize;
    let mut spillovers = 0usize;

    // Stream the shared workload — the template is sampled from the
    // *base* seed, so every router strategy (and the quiet twin of an
    // outage scenario) routes an identical arrival sequence.
    for (at, wf) in workload::plan_iter(&spec.base.workload, &spec.base.task, None)? {
        for engine in &mut engines {
            engine.run_until(at);
        }
        let inputs: Vec<RouteInput> = engines
            .iter()
            .enumerate()
            .map(|(i, engine)| {
                let (capacity_cpu, capacity_mem) = engine.cluster_capacity();
                let (residual_cpu, residual_mem) = engine.cluster_residual();
                let cycles = engine.serve_cycle_count().max(1);
                RouteInput {
                    cluster: i,
                    name: fed.clusters[i].name.clone(),
                    weight: fed.clusters[i].weight,
                    queue_depth: engine.alloc_queue_depth(),
                    stale_rate: engine.stale_snapshot_cycle_count() as f64 / cycles as f64,
                    capacity_cpu,
                    capacity_mem,
                    residual_cpu,
                    residual_mem,
                    forecast: engine.current_forecast(fed.submit_horizon_s),
                }
            })
            .collect();
        let order = router.rank(&inputs);
        anyhow::ensure!(
            is_permutation(&order, n),
            "router '{}' returned an invalid ranking {:?} for {} clusters",
            router.name(),
            order,
            n
        );
        let overloaded = |i: usize| {
            inputs[i].capacity_cpu <= 0.0
                || inputs[i].queue_depth > fed.spill_queue_depth
                || inputs[i].stale_rate > fed.spill_stale_rate
        };
        // First preference that isn't overloaded; when everything is,
        // fall back to the best cluster that at least has live nodes
        // (placing on a dead cluster would strand the workflow forever).
        let chosen = order
            .iter()
            .copied()
            .find(|&i| !overloaded(i))
            .or_else(|| order.iter().copied().find(|&i| inputs[i].capacity_cpu > 0.0))
            .unwrap_or(order[0]);
        first_choice[order[0]] += 1;
        if chosen != order[0] {
            spillovers += 1;
            spill_in[chosen] += 1;
        }
        placements[chosen] += 1;
        routed += 1;
        engines[chosen].submit_at(at, wf, 1)?;
    }

    // Drain every member to completion under the shared clock.
    let mut outcomes = Vec::with_capacity(n);
    for (i, mut engine) in engines.into_iter().enumerate() {
        while engine.step() {}
        anyhow::ensure!(
            !engine.event_cap_hit(),
            "federation cluster '{}' hit the event cap before draining",
            fed.clusters[i].name
        );
        outcomes.push(engine.finish());
    }

    let clusters: Vec<ClusterReport> = outcomes
        .iter()
        .enumerate()
        .map(|(i, o)| ClusterReport {
            name: fed.clusters[i].name.clone(),
            nodes: nodes[i],
            weight: fed.clusters[i].weight,
            first_choice: first_choice[i],
            placements: placements[i],
            spill_in: spill_in[i],
            workflows_completed: o.summary.workflows_completed,
            tasks_completed: o.summary.tasks_completed,
            total_duration_min: o.summary.total_duration_min,
            avg_workflow_duration_min: o.summary.avg_workflow_duration_min,
            cpu_usage: o.summary.cpu_usage,
            mem_usage: o.summary.mem_usage,
            alloc_waits: o.summary.alloc_waits,
            evictions: o.summary.evictions,
            stale_snapshot_cycles: o.summary.stale_snapshot_cycles,
        })
        .collect();

    let workflows_completed: usize = clusters.iter().map(|c| c.workflows_completed).sum();
    let tasks_completed: usize = clusters.iter().map(|c| c.tasks_completed).sum();
    let total_duration_min =
        clusters.iter().map(|c| c.total_duration_min).fold(0.0, f64::max);
    let avg_workflow_duration_min = if workflows_completed > 0 {
        clusters
            .iter()
            .map(|c| c.avg_workflow_duration_min * c.workflows_completed as f64)
            .sum::<f64>()
            / workflows_completed as f64
    } else {
        0.0
    };
    let total_nodes: usize = clusters.iter().map(|c| c.nodes).sum();
    let node_weighted = |pick: fn(&ClusterReport) -> f64| -> f64 {
        if total_nodes == 0 {
            return 0.0;
        }
        clusters.iter().map(|c| pick(c) * c.nodes as f64).sum::<f64>() / total_nodes as f64
    };

    let summary = FederatedSummary {
        router: fed.router.label(),
        clusters,
        routed,
        spillovers,
        workflows_completed,
        tasks_completed,
        total_duration_min,
        avg_workflow_duration_min,
        cpu_usage: node_weighted(|c| c.cpu_usage),
        mem_usage: node_weighted(|c| c.mem_usage),
    };
    Ok(FederationResult { summary, outcomes })
}

/// Run many federations on a campaign-style work-stealing pool, results
/// in input order. Each federation is built, run and folded entirely
/// inside one worker (engines are not `Send` and never migrate);
/// determinism across thread counts follows from per-spec seeding plus
/// the final re-sort.
pub fn run_many(specs: &[FederationSpec], threads: usize) -> anyhow::Result<Vec<FederationResult>> {
    let threads = if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
    }
    .clamp(1, specs.len().max(1));
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, anyhow::Result<FederationResult>)>();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= specs.len() {
                    break;
                }
                let result = run_spec(&specs[i]);
                if tx.send((i, result)).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);

    let mut slots: Vec<Option<anyhow::Result<FederationResult>>> =
        (0..specs.len()).map(|_| None).collect();
    for (i, result) in rx {
        slots[i] = Some(result);
    }
    let mut results = Vec::with_capacity(specs.len());
    for (spec, slot) in specs.iter().zip(slots) {
        match slot {
            Some(Ok(r)) => results.push(r),
            Some(Err(e)) => anyhow::bail!("federation '{}' failed: {e}", spec.name),
            None => anyhow::bail!("federation '{}' produced no result (worker died)", spec.name),
        }
    }
    Ok(results)
}

/// Fold a federation result into a single [`RunOutcome`] shaped like an
/// ordinary engine run — how federated cells ride the campaign's
/// summary/comparison machinery. Counters sum across members; rates
/// and quantiles are completion- or points-weighted means (documented
/// approximations — per-cluster truth lives in the
/// [`FederatedSummary`]); the collector is empty (federated cells carry
/// no merged sample streams).
pub fn fold_outcome(result: FederationResult) -> RunOutcome {
    let fs = &result.summary;
    let outs = &result.outcomes;
    let sum_u = |pick: fn(&RunSummary) -> usize| -> usize {
        outs.iter().map(|o| pick(&o.summary)).sum()
    };
    let sum_f = |pick: fn(&RunSummary) -> f64| -> f64 {
        outs.iter().map(|o| pick(&o.summary)).sum()
    };
    // Weighted means over member clusters; zero total weight → 0.
    let weighted = |value: fn(&RunSummary) -> f64, weight: fn(&RunSummary) -> f64| -> f64 {
        let total: f64 = outs.iter().map(|o| weight(&o.summary)).sum();
        if total > 0.0 {
            outs.iter().map(|o| value(&o.summary) * weight(&o.summary)).sum::<f64>() / total
        } else {
            0.0
        }
    };
    let by_completions = |value: fn(&RunSummary) -> f64| -> f64 {
        weighted(value, |s| s.workflows_completed as f64)
    };
    let by_points = |value: fn(&RunSummary) -> f64| -> f64 {
        weighted(value, |s| s.forecast_points as f64)
    };
    let mut phases = PhaseBreakdown::default();
    for o in outs {
        let p = o.summary.phases;
        phases.serve_cycles += p.serve_cycles;
        phases.plan_calls += p.plan_calls;
        phases.schedule_calls += p.schedule_calls;
        phases.snapshot_applies += p.snapshot_applies;
        phases.forecast_observes += p.forecast_observes;
        phases.forecast_predicts += p.forecast_predicts;
        phases.chaos_events += p.chaos_events;
        phases.serve_wall_ns += p.serve_wall_ns;
        phases.plan_wall_ns += p.plan_wall_ns;
        phases.schedule_wall_ns += p.schedule_wall_ns;
        phases.snapshot_wall_ns += p.snapshot_wall_ns;
        phases.forecast_wall_ns += p.forecast_wall_ns;
        phases.chaos_wall_ns += p.chaos_wall_ns;
    }
    let summary = RunSummary {
        total_duration_min: fs.total_duration_min,
        avg_workflow_duration_min: fs.avg_workflow_duration_min,
        cpu_usage: fs.cpu_usage,
        mem_usage: fs.mem_usage,
        workflows_completed: fs.workflows_completed,
        tasks_completed: fs.tasks_completed,
        oom_events: sum_u(|s| s.oom_events),
        alloc_waits: sum_u(|s| s.alloc_waits),
        sla_violations: sum_u(|s| s.sla_violations),
        evictions: sum_u(|s| s.evictions),
        nodes_joined: sum_u(|s| s.nodes_joined),
        nodes_removed: sum_u(|s| s.nodes_removed),
        forecast_points: sum_u(|s| s.forecast_points),
        forecast_mape_cpu: by_points(|s| s.forecast_mape_cpu),
        forecast_mape_mem: by_points(|s| s.forecast_mape_mem),
        forecast_rmse_cpu: by_points(|s| s.forecast_rmse_cpu),
        forecast_rmse_mem: by_points(|s| s.forecast_rmse_mem),
        hog_stolen_cpu_s: sum_f(|s| s.hog_stolen_cpu_s),
        hog_stolen_mem_s: sum_f(|s| s.hog_stolen_mem_s),
        stale_snapshot_cycles: sum_u(|s| s.stale_snapshot_cycles),
        double_alloc_attempts: sum_u(|s| s.double_alloc_attempts),
        wf_duration_p50_s: by_completions(|s| s.wf_duration_p50_s),
        wf_duration_p95_s: by_completions(|s| s.wf_duration_p95_s),
        phases,
    };
    RunOutcome {
        summary,
        metrics: Collector::new(),
        pods_created: outs.iter().map(|o| o.pods_created).sum(),
        store_list_calls: outs.iter().map(|o| o.store_list_calls).sum(),
        serve_cycles: outs.iter().map(|o| o.serve_cycles).sum(),
        statestore_writes: outs.iter().map(|o| o.statestore_writes).sum(),
        namespaces_remaining: outs.iter().map(|o| o.namespaces_remaining).sum(),
        pods_remaining: outs.iter().map(|o| o.pods_remaining).sum(),
        pods_evicted: outs.iter().map(|o| o.pods_evicted).sum(),
        evicted_rescheduled: outs.iter().map(|o| o.evicted_rescheduled).sum(),
        evicted_unresolved: outs.iter().map(|o| o.evicted_unresolved).sum(),
        tasks_unfinished: outs.iter().map(|o| o.tasks_unfinished).sum(),
        hog_stolen_cpu_s: outs.iter().map(|o| o.hog_stolen_cpu_s).sum(),
        hog_stolen_mem_s: outs.iter().map(|o| o.hog_stolen_mem_s).sum(),
        stale_snapshot_cycles: outs.iter().map(|o| o.stale_snapshot_cycles).sum(),
        double_alloc_attempts: outs.iter().map(|o| o.double_alloc_attempts).sum(),
        spans: Vec::new(),
    }
}

/// Campaign entry point: run `cfg` as a homogeneous federation of
/// `clusters` identical shards (each a full copy of the cell's cluster
/// config) behind `router`, folded to one [`RunOutcome`]. The `clusters`
/// campaign axis dispatches here for every cell with more than one
/// cluster.
pub fn run_sharded(
    cfg: &ExperimentConfig,
    clusters: usize,
    router: &RouterSpec,
) -> anyhow::Result<RunOutcome> {
    anyhow::ensure!(clusters > 1, "sharded runs need at least two clusters");
    let spec = FederationSpec {
        name: format!("sharded-{clusters}x"),
        base: cfg.clone(),
        federation: FederationConfig::homogeneous(clusters, router.clone()),
    };
    Ok(fold_outcome(run_spec(&spec)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArrivalPattern, ClusterSpec};

    fn tiny_spec(router: &str) -> FederationSpec {
        let mut base = ExperimentConfig::default();
        base.workload.pattern = ArrivalPattern::Constant { bursts: 2, per_burst: 2 };
        base.workload.seed = 7;
        FederationSpec {
            name: format!("tiny-{router}"),
            base,
            federation: FederationConfig {
                clusters: vec![
                    ClusterSpec::named("small").with_nodes(2),
                    ClusterSpec::named("big").with_nodes(6).with_weight(3.0),
                ],
                router: RouterSpec::named(router),
                ..FederationConfig::default()
            },
        }
    }

    #[test]
    fn federation_runs_and_accounts_every_placement() {
        let result = run_spec(&tiny_spec("round-robin")).unwrap();
        let s = &result.summary;
        assert_eq!(s.routed, 4);
        assert_eq!(s.clusters.iter().map(|c| c.placements).sum::<usize>(), 4);
        assert_eq!(s.workflows_completed, 4);
        assert_eq!(s.clusters.len(), 2);
        assert!(s.total_duration_min > 0.0);
        // The fold mirrors the federation aggregates.
        let folded = fold_outcome(result);
        assert_eq!(folded.summary.workflows_completed, 4);
        assert_eq!(folded.summary.total_duration_min, s.total_duration_min);
    }

    #[test]
    fn federation_is_bit_deterministic() {
        for router in ["round-robin", "least-queue", "forecast-headroom", "weighted"] {
            let a = run_spec(&tiny_spec(router)).unwrap().summary;
            let b = run_spec(&tiny_spec(router)).unwrap().summary;
            assert_eq!(
                a.total_duration_min.to_bits(),
                b.total_duration_min.to_bits(),
                "router {router}"
            );
            assert_eq!(a.spillovers, b.spillovers, "router {router}");
            for (ca, cb) in a.clusters.iter().zip(&b.clusters) {
                assert_eq!(ca.placements, cb.placements, "router {router}");
                assert_eq!(
                    ca.avg_workflow_duration_min.to_bits(),
                    cb.avg_workflow_duration_min.to_bits(),
                    "router {router}"
                );
            }
        }
    }

    #[test]
    fn prometheus_exposition_is_structurally_valid() {
        let result = run_spec(&tiny_spec("weighted")).unwrap();
        let text = result.summary.prometheus_metrics();
        assert!(text.contains("ka_fed_routed_total 4"));
        assert!(text.contains("ka_fed_placements_total{cluster=\"small\"}"));
        assert!(text.contains("ka_fed_cluster_nodes{cluster=\"big\"} 6"));
        crate::obs::expo::validate(&text).unwrap();
    }

    #[test]
    fn run_sharded_matches_campaign_contract() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.pattern = ArrivalPattern::Constant { bursts: 2, per_burst: 2 };
        let outcome = run_sharded(&cfg, 2, &RouterSpec::named("least-queue")).unwrap();
        assert_eq!(outcome.summary.workflows_completed, 4);
        assert!(run_sharded(&cfg, 1, &RouterSpec::default()).is_err());
    }
}
