//! Global routing strategies: given one [`RouteInput`] per member
//! cluster, a [`Router`] returns a preference-ordered ranking. The
//! federation runner places each arriving workflow on the first ranked
//! cluster that isn't overloaded (spillover handles the rest — see
//! [`super::run_spec`]).
//!
//! Routers are deterministic state machines: identical input sequences
//! must yield identical rankings, because a federation run's bit-exact
//! reproducibility (golden-locked) rides on every placement decision.
//! All scores are derived from engine counters and forecasts that are
//! finite by construction, so `f64::total_cmp` ordering is never asked
//! to rank a NaN.

use crate::forecast::DemandForecast;

/// Per-cluster routing signals sampled at a submission instant, after
/// every engine has caught up to the shared virtual clock.
#[derive(Debug, Clone)]
pub struct RouteInput {
    /// Cluster index in federation order.
    pub cluster: usize,
    /// Cluster name (report/metric label).
    pub name: String,
    /// Static routing weight from the [`crate::config::ClusterSpec`].
    pub weight: f64,
    /// Current allocation-queue depth (FCFS backlog).
    pub queue_depth: usize,
    /// Stale serve cycles / total serve cycles so far.
    pub stale_rate: f64,
    /// Total allocatable capacity over live nodes (cpu_milli, mem_mi).
    pub capacity_cpu: f64,
    pub capacity_mem: f64,
    /// Capacity minus requests held by live pods (cpu_milli, mem_mi).
    pub residual_cpu: f64,
    pub residual_mem: f64,
    /// The cluster's own demand forecast at the submission horizon;
    /// `None` when forecasting is off or unwarmed.
    pub forecast: Option<DemandForecast>,
}

/// A global routing strategy. `rank` returns cluster indices best
/// first; it must be a permutation of `0..inputs.len()` (the runner
/// enforces this). `&mut self` lets stateful strategies (round-robin
/// rotation, smooth weighted round-robin credit) evolve between
/// decisions.
pub trait Router {
    fn name(&self) -> &str;
    fn rank(&mut self, inputs: &[RouteInput]) -> Vec<usize>;
}

/// Cycle clusters in federation order, advancing one slot per decision.
/// The zero-signal baseline every other router is compared against.
#[derive(Debug, Default)]
pub struct RoundRobinRouter {
    next: usize,
}

impl RoundRobinRouter {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Router for RoundRobinRouter {
    fn name(&self) -> &str {
        "round-robin"
    }

    fn rank(&mut self, inputs: &[RouteInput]) -> Vec<usize> {
        let n = inputs.len();
        if n == 0 {
            return Vec::new();
        }
        let start = self.next % n;
        self.next = (start + 1) % n;
        (0..n).map(|k| (start + k) % n).collect()
    }
}

/// Shallowest allocation queue first (ties broken by cluster index) —
/// reactive load balancing on the one signal a real federation gateway
/// always has.
#[derive(Debug, Default)]
pub struct LeastQueueRouter;

impl LeastQueueRouter {
    pub fn new() -> Self {
        Self
    }
}

impl Router for LeastQueueRouter {
    fn name(&self) -> &str {
        "least-queue"
    }

    fn rank(&mut self, inputs: &[RouteInput]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..inputs.len()).collect();
        order.sort_by_key(|&i| (inputs[i].queue_depth, i));
        order
    }
}

/// Largest forecast-adjusted headroom first: residual capacity minus
/// the *additional* demand each cluster's own forecaster predicts at
/// the submission horizon, normalized by capacity so small and large
/// clusters compare fairly (the min of the CPU and memory fractions —
/// the binding dimension decides). Without a forecast the predicted
/// extra demand is zero and the router degrades to proportional
/// residual headroom. `margin` (default 0) is subtracted from every
/// score — a reserve fraction the router pretends is already spent.
#[derive(Debug)]
pub struct ForecastHeadroomRouter {
    margin: f64,
}

impl ForecastHeadroomRouter {
    pub fn new(margin: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(
            margin.is_finite() && margin >= 0.0,
            "forecast-headroom margin must be finite and >= 0, got {margin}"
        );
        Ok(Self { margin })
    }

    /// Normalized headroom score for one cluster; finite whenever the
    /// inputs are (and they are by construction).
    fn score(&self, input: &RouteInput) -> f64 {
        let frac = |capacity: f64, residual: f64, predicted: f64| -> f64 {
            if capacity <= 0.0 {
                // A cluster with no live nodes has no headroom at all.
                return -1.0;
            }
            let held = capacity - residual;
            let extra = (predicted - held).max(0.0);
            (residual - extra) / capacity
        };
        let (pred_cpu, pred_mem) = match &input.forecast {
            Some(f) => (f.cpu_demand, f.mem_demand),
            // No forecast: predicted demand = current demand, extra = 0.
            None => (0.0, 0.0),
        };
        let cpu = frac(input.capacity_cpu, input.residual_cpu, pred_cpu);
        let mem = frac(input.capacity_mem, input.residual_mem, pred_mem);
        cpu.min(mem) - self.margin
    }
}

impl Router for ForecastHeadroomRouter {
    fn name(&self) -> &str {
        "forecast-headroom"
    }

    fn rank(&mut self, inputs: &[RouteInput]) -> Vec<usize> {
        let scores: Vec<f64> = inputs.iter().map(|i| self.score(i)).collect();
        let mut order: Vec<usize> = (0..inputs.len()).collect();
        order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
        order
    }
}

/// Smooth weighted round-robin (the nginx algorithm) over the static
/// cluster weights: each decision every cluster earns its weight in
/// credit, the highest credit wins and pays back the total — a maximally
/// even interleaving matching the weight ratios, with no randomness.
#[derive(Debug, Default)]
pub struct WeightedRouter {
    credit: Vec<f64>,
}

impl WeightedRouter {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Router for WeightedRouter {
    fn name(&self) -> &str {
        "weighted"
    }

    fn rank(&mut self, inputs: &[RouteInput]) -> Vec<usize> {
        let n = inputs.len();
        if n == 0 {
            return Vec::new();
        }
        self.credit.resize(n, 0.0);
        let mut total = 0.0;
        for (i, input) in inputs.iter().enumerate() {
            self.credit[i] += input.weight;
            total += input.weight;
        }
        let mut order: Vec<usize> = (0..n).collect();
        let credit = &self.credit;
        order.sort_by(|&a, &b| credit[b].total_cmp(&credit[a]).then(a.cmp(&b)));
        self.credit[order[0]] -= total;
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(cluster: usize, weight: f64, queue_depth: usize) -> RouteInput {
        RouteInput {
            cluster,
            name: format!("c{cluster}"),
            weight,
            queue_depth,
            stale_rate: 0.0,
            capacity_cpu: 48_000.0,
            capacity_mem: 61_440.0,
            residual_cpu: 48_000.0,
            residual_mem: 61_440.0,
            forecast: None,
        }
    }

    #[test]
    fn round_robin_cycles_in_order() {
        let inputs = vec![input(0, 1.0, 0), input(1, 1.0, 0), input(2, 1.0, 0)];
        let mut r = RoundRobinRouter::new();
        assert_eq!(r.rank(&inputs), vec![0, 1, 2]);
        assert_eq!(r.rank(&inputs), vec![1, 2, 0]);
        assert_eq!(r.rank(&inputs), vec![2, 0, 1]);
        assert_eq!(r.rank(&inputs), vec![0, 1, 2]);
    }

    #[test]
    fn least_queue_prefers_shallow_backlogs_with_index_ties() {
        let inputs = vec![input(0, 1.0, 5), input(1, 1.0, 2), input(2, 1.0, 2)];
        let mut r = LeastQueueRouter::new();
        assert_eq!(r.rank(&inputs), vec![1, 2, 0]);
    }

    #[test]
    fn forecast_headroom_ranks_by_adjusted_residual() {
        let mut a = input(0, 1.0, 0);
        let mut b = input(1, 1.0, 0);
        // b has half its capacity held already.
        b.residual_cpu = 24_000.0;
        b.residual_mem = 30_720.0;
        let mut r = ForecastHeadroomRouter::new(0.0).unwrap();
        assert_eq!(r.rank(&[a.clone(), b.clone()]), vec![0, 1]);
        // A forecast predicting a demand surge on `a` flips the order.
        a.forecast = Some(DemandForecast {
            horizon_s: 60.0,
            cpu_demand: 40_000.0,
            mem_demand: 51_200.0,
            queue_len: 0.0,
            arrival_rate: 0.0,
        });
        assert_eq!(r.rank(&[a.clone(), b.clone()]), vec![1, 0]);
        // A dead cluster (no live nodes) always sorts last.
        let mut dead = input(2, 1.0, 0);
        dead.capacity_cpu = 0.0;
        dead.capacity_mem = 0.0;
        dead.residual_cpu = 0.0;
        dead.residual_mem = 0.0;
        assert_eq!(r.rank(&[a, b, dead])[2], 2);
    }

    #[test]
    fn forecast_headroom_rejects_bad_margins() {
        assert!(ForecastHeadroomRouter::new(f64::NAN).is_err());
        assert!(ForecastHeadroomRouter::new(-0.1).is_err());
        assert!(ForecastHeadroomRouter::new(0.1).is_ok());
    }

    #[test]
    fn weighted_interleaves_proportionally() {
        // Weights 3:1 — over 4 decisions the heavy cluster wins 3.
        let inputs = vec![input(0, 3.0, 0), input(1, 1.0, 0)];
        let mut r = WeightedRouter::new();
        let wins: Vec<usize> = (0..4).map(|_| r.rank(&inputs)[0]).collect();
        assert_eq!(wins.iter().filter(|&&w| w == 0).count(), 3);
        assert_eq!(wins.iter().filter(|&&w| w == 1).count(), 1);
        // Smooth WRR spreads the light cluster's turn mid-sequence.
        assert_eq!(wins, vec![0, 0, 1, 0]);
    }

    #[test]
    fn rankings_are_permutations() {
        let inputs: Vec<RouteInput> =
            (0..5).map(|i| input(i, 1.0 + i as f64, i * 2)).collect();
        let mut routers: Vec<Box<dyn Router>> = vec![
            Box::new(RoundRobinRouter::new()),
            Box::new(LeastQueueRouter::new()),
            Box::new(ForecastHeadroomRouter::new(0.05).unwrap()),
            Box::new(WeightedRouter::new()),
        ];
        for router in &mut routers {
            for _ in 0..7 {
                let mut order = router.rank(&inputs);
                order.sort_unstable();
                assert_eq!(order, vec![0, 1, 2, 3, 4], "router {}", router.name());
            }
        }
    }
}
