//! PJRT-backed decision backend.
//!
//! Loads `aras_decide.hlo.txt` (HLO text — see aot.py for why text, not
//! serialized proto), compiles it once on the PJRT CPU client, and serves
//! ARAS decisions by padding live cluster state to the artifact's static
//! capacities. Inputs larger than the capacities are reduced *losslessly
//! where possible*: task records beyond `cap_tasks` are pre-aggregated
//! into a single synthetic record inside the window (the overlap kernel
//! is a masked sum, so folding excess records into one preserves the
//! result exactly — **for the window the fold was computed against**;
//! a chunk whose lanes disagree on the window therefore executes per
//! item when records overflow, see `runtime/lanes.rs`).
//!
//! The artifact is batch-shaped (`cap_batch` request lanes over one
//! shared record/node state — the shape the Pallas `alloc_eval` kernel
//! is written in), so this backend is a first-class batched implementor
//! of [`DecisionBackend::decide_batch`]: when a queue-serve cycle's
//! requests share a record view (always true with lookahead disabled,
//! or an empty state store), the whole cycle executes in
//! `ceil(n / cap_batch)` device calls instead of `n`. Batches whose
//! members see different record overlays (the sequential-equivalence
//! overlay of `AdaptivePolicy` with lookahead on) fall back to per-item
//! execution — exactness always wins over amortization.

use std::path::Path;

use crate::resources::adaptive::{DecisionBackend, DecisionInputs, DecisionOutputs};

use super::artifact::Manifest;
use super::lanes;

/// A compiled ARAS decision module on the PJRT CPU client.
pub struct PjrtBackend {
    exe: xla::PjRtLoadedExecutable,
    cap_tasks: usize,
    cap_nodes: usize,
    cap_batch: usize,
    executions: u64,
}

impl PjrtBackend {
    /// Load from an artifacts directory (see [`super::find_artifacts_dir`]).
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let file = manifest
            .file_of("aras_decide")
            .ok_or_else(|| anyhow::anyhow!("manifest has no aras_decide artifact"))?;
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(dir.join(file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Self {
            exe,
            cap_tasks: manifest.cap_tasks,
            cap_nodes: manifest.cap_nodes,
            cap_batch: manifest.cap_batch,
            executions: 0,
        })
    }

    /// Load from the auto-discovered artifacts directory.
    pub fn load_default() -> anyhow::Result<Self> {
        let dir = super::artifact::find_artifacts_dir()
            .ok_or_else(|| anyhow::anyhow!("artifacts/ not found — run `make artifacts`"))?;
        Self::load(&dir)
    }

    pub fn executions(&self) -> u64 {
        self.executions
    }

    pub fn capacities(&self) -> (usize, usize, usize) {
        (self.cap_tasks, self.cap_nodes, self.cap_batch)
    }

    /// Pad records to capacity. When — and only when — they overflow
    /// `cap_tasks`, the tail is folded into one synthetic record
    /// filtered by and pinned inside `inputs`' window (sum-preserving
    /// *for that window*: the fold is a per-window quantity, which is
    /// why [`PjrtBackend::decide_batch`] refuses to share a fold across
    /// lanes with divergent windows). Exactly-at-capacity inputs fill
    /// the direct slots with no fold (`lanes::direct_records`).
    fn pad_records(&self, inputs: &DecisionInputs) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let t = self.cap_tasks;
        let mut ts = vec![0.0f32; t];
        let mut cpu = vec![0.0f32; t];
        let mut mem = vec![0.0f32; t];
        let mut valid = vec![0.0f32; t];
        let n_direct = lanes::direct_records(inputs.records.len(), t);
        for (i, &(rt, rc, rm)) in inputs.records.iter().take(n_direct).enumerate() {
            ts[i] = rt;
            cpu[i] = rc;
            mem[i] = rm;
            valid[i] = 1.0;
        }
        if lanes::overflow_fold_needed(inputs.records.len(), t) {
            let (fold_cpu, fold_mem) =
                lanes::fold_tail(&inputs.records, n_direct, inputs.win_start, inputs.win_end);
            let slot = t - 1;
            ts[slot] = inputs.win_start;
            cpu[slot] = fold_cpu;
            mem[slot] = fold_mem;
            valid[slot] = 1.0;
        }
        (ts, cpu, mem, valid)
    }

    /// Execute up to `cap_batch` requests that share one record/node
    /// view in a single device call: records and nodes come from
    /// `chunk[0]`, each request fills its own (window, req) lane.
    fn execute_chunk(&mut self, chunk: &[DecisionInputs]) -> Vec<DecisionOutputs> {
        assert!(!chunk.is_empty() && chunk.len() <= self.cap_batch);
        // The record buffer — including any overflow fold — is shared
        // by every lane, but a fold is filtered and pinned by *one*
        // window. decide_batch must not send a chunk here that would
        // fold across divergent lane windows (each other lane would
        // silently receive a wrong window-demand sum).
        debug_assert!(
            !lanes::overflow_fold_needed(chunk[0].records.len(), self.cap_tasks)
                || lanes::windows_identical(chunk),
            "shared overflow fold requires identical lane windows"
        );
        self.executions += 1;
        let shared = &chunk[0];
        let (ts, cpu, mem, valid) = self.pad_records(shared);

        let b = self.cap_batch;
        let mut win_s = vec![0.0f32; b];
        let mut win_e = vec![0.0f32; b];
        let mut req_c = vec![0.0f32; b];
        let mut req_m = vec![0.0f32; b];
        for (lane, inputs) in chunk.iter().enumerate() {
            win_s[lane] = inputs.win_start;
            win_e[lane] = inputs.win_end;
            req_c[lane] = inputs.req_cpu;
            req_m[lane] = inputs.req_mem;
        }

        let n = self.cap_nodes;
        assert!(
            shared.node_res.len() <= n,
            "cluster has {} nodes but artifact capacity is {n}; regenerate artifacts",
            shared.node_res.len()
        );
        let mut node_c = vec![0.0f32; n];
        let mut node_m = vec![0.0f32; n];
        let mut node_v = vec![0.0f32; n];
        for (i, &(c, m)) in shared.node_res.iter().enumerate() {
            node_c[i] = c;
            node_m[i] = m;
            node_v[i] = 1.0;
        }

        let lits = [
            xla::Literal::vec1(&ts),
            xla::Literal::vec1(&cpu),
            xla::Literal::vec1(&mem),
            xla::Literal::vec1(&valid),
            xla::Literal::vec1(&win_s),
            xla::Literal::vec1(&win_e),
            xla::Literal::vec1(&req_c),
            xla::Literal::vec1(&req_m),
            xla::Literal::vec1(&node_c),
            xla::Literal::vec1(&node_m),
            xla::Literal::vec1(&node_v),
            xla::Literal::from(shared.alpha),
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .expect("pjrt execute")[0][0]
            .to_literal_sync()
            .expect("to_literal");
        let (a_cpu, a_mem, r_cpu, r_mem) = result.to_tuple4().expect("4-tuple output");
        let a_cpu = a_cpu.to_vec::<f32>().expect("f32 vec");
        let a_mem = a_mem.to_vec::<f32>().expect("f32 vec");
        let r_cpu = r_cpu.to_vec::<f32>().expect("f32 vec");
        let r_mem = r_mem.to_vec::<f32>().expect("f32 vec");
        (0..chunk.len())
            .map(|lane| DecisionOutputs {
                alloc_cpu: a_cpu[lane],
                alloc_mem: a_mem[lane],
                request_cpu: r_cpu[lane],
                request_mem: r_mem[lane],
            })
            .collect()
    }
}

impl DecisionBackend for PjrtBackend {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn decide(&mut self, inputs: &DecisionInputs) -> DecisionOutputs {
        self.execute_chunk(std::slice::from_ref(inputs))
            .into_iter()
            .next()
            .expect("one output per lane")
    }

    fn decide_batch(&mut self, inputs: &[DecisionInputs]) -> Vec<DecisionOutputs> {
        if inputs.len() > 1 && lanes::shares_record_view(inputs) {
            let overflow = lanes::overflow_fold_needed(inputs[0].records.len(), self.cap_tasks);
            let mut out = Vec::with_capacity(inputs.len());
            for chunk in inputs.chunks(self.cap_batch) {
                if overflow && !lanes::windows_identical(chunk) {
                    // The shared record buffer would carry an overflow
                    // fold filtered by one lane's window — wrong for
                    // every other lane. The artifact has no per-lane
                    // record slots, so exactness demands per-item
                    // execution here (the native backend folds per
                    // lane instead and keeps the chunk).
                    out.extend(chunk.iter().map(|i| self.decide(i)));
                } else {
                    out.extend(self.execute_chunk(chunk));
                }
            }
            out
        } else {
            // Per-item record overlays (ARAS lookahead): exactness over
            // amortization.
            inputs.iter().map(|i| self.decide(i)).collect()
        }
    }
}
