//! Artifact discovery + manifest parsing.
//!
//! `aot.py` writes a `manifest.json` describing each lowered module and
//! the static capacities (task records / nodes / batch lanes) the HLO
//! shapes were fixed to. The Rust side reads capacities from the manifest
//! rather than hard-coding them, so regenerating artifacts with different
//! capacities requires no Rust change.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub cap_tasks: usize,
    pub cap_nodes: usize,
    pub cap_batch: usize,
    /// Sample capacity of the usage_integral artifact (None in manifests
    /// predating it).
    pub cap_samples: Option<usize>,
    /// Artifact name -> file name.
    pub files: Vec<(String, String)>,
}

impl Manifest {
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let j = Json::parse(text)?;
        // Capacities are HLO shape dimensions: they must be >= 1. An
        // unchecked `as usize` would silently wrap a negative value to
        // a huge capacity (and 0 would make every padder misbehave).
        let validate = |k: &str, v: i64| -> anyhow::Result<usize> {
            anyhow::ensure!(
                v >= 1,
                "manifest capacities.{k} must be >= 1, got {v} \
                 (fix manifest.json or regenerate artifacts)"
            );
            Ok(v as usize)
        };
        let cap = |k: &str| -> anyhow::Result<usize> {
            let v = j
                .at(&["capacities", k])
                .and_then(|v| v.as_i64())
                .ok_or_else(|| anyhow::anyhow!("manifest missing capacities.{k}"))?;
            validate(k, v)
        };
        let mut files = Vec::new();
        if let Some(arts) = j.get("artifacts").and_then(|v| v.as_obj()) {
            for (name, entry) in arts {
                let file = entry
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow::anyhow!("artifact {name} missing file"))?;
                files.push((name.clone(), file.to_string()));
            }
        }
        anyhow::ensure!(!files.is_empty(), "manifest lists no artifacts");
        Ok(Manifest {
            cap_tasks: cap("tasks")?,
            cap_nodes: cap("nodes")?,
            cap_batch: cap("batch")?,
            cap_samples: j
                .at(&["capacities", "samples"])
                .and_then(|v| v.as_i64())
                .map(|v| validate("samples", v))
                .transpose()?,
            files,
        })
    }

    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("reading {}/manifest.json: {e} (run `make artifacts`)", dir.display()))?;
        Self::parse(&text)
    }

    pub fn file_of(&self, name: &str) -> Option<&str> {
        self.files.iter().find(|(n, _)| n == name).map(|(_, f)| f.as_str())
    }
}

/// Locate the artifacts directory: `$KA_ARTIFACTS` first, then
/// `artifacts/` found by walking up from the **current directory** (so
/// tests and examples work from any working directory inside the repo).
pub fn find_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("KA_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": "hlo-text",
        "capacities": {"tasks": 512, "nodes": 32, "batch": 8},
        "artifacts": {
            "aras_decide": {"file": "aras_decide.hlo.txt", "inputs": [], "outputs": []}
        }
    }"#;

    #[test]
    fn parses_capacities_and_files() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.cap_tasks, 512);
        assert_eq!(m.cap_nodes, 32);
        assert_eq!(m.cap_batch, 8);
        assert_eq!(m.file_of("aras_decide"), Some("aras_decide.hlo.txt"));
        assert_eq!(m.file_of("nope"), None);
    }

    #[test]
    fn rejects_empty_manifest() {
        assert!(Manifest::parse(r#"{"capacities":{"tasks":1,"nodes":1,"batch":1},"artifacts":{}}"#).is_err());
        assert!(Manifest::parse(r#"{"artifacts":{"a":{"file":"x"}}}"#).is_err());
    }

    #[test]
    fn rejects_non_positive_capacities() {
        // A negative capacity cast straight to usize would wrap to a
        // huge value; zero breaks every padder. Both must error with
        // the offending key and value.
        for (k, v) in [("tasks", -512), ("nodes", 0), ("batch", -1)] {
            let (tasks, nodes, batch) = match k {
                "tasks" => (v, 32, 8),
                "nodes" => (512, v, 8),
                _ => (512, 32, v),
            };
            let text = format!(
                r#"{{"capacities":{{"tasks":{tasks},"nodes":{nodes},"batch":{batch}}},
                    "artifacts":{{"a":{{"file":"x"}}}}}}"#
            );
            let err = Manifest::parse(&text).unwrap_err().to_string();
            assert!(err.contains(&format!("capacities.{k}")), "{err}");
            assert!(err.contains(&format!("got {v}")), "{err}");
        }
        let bad_samples = r#"{"capacities":{"tasks":1,"nodes":1,"batch":1,"samples":0},
                              "artifacts":{"a":{"file":"x"}}}"#;
        assert!(Manifest::parse(bad_samples).unwrap_err().to_string().contains("samples"));
    }
}
