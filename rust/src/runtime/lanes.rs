//! Lane-filling rules shared by the batched decision backends.
//!
//! The compiled artifact (and its native interpreter twin) is
//! batch-shaped: one shared record/node view, `cap_batch` request lanes
//! each with its own `(win_start, win_end, req_cpu, req_mem)`. These
//! helpers encode the two padding decisions every batched backend must
//! make identically:
//!
//! 1. **When does record overflow require folding?** Only when the live
//!    records outnumber `cap_tasks`. Exactly `cap_tasks` records fill
//!    the direct slots with nothing left over — folding there is at
//!    best wasted work, and inside a multi-lane chunk it is *wrong*
//!    (see below).
//! 2. **When is a shared fold sound?** The overlap kernel is a masked
//!    sum per lane, so excess records can be pre-aggregated into one
//!    synthetic record — but the filter "is this record inside the
//!    window?" and the pin position are **per-lane** quantities. A
//!    fold computed against one lane's window silently hands every
//!    other lane a wrong window-demand sum. A backend whose record
//!    buffer is shared across lanes (PJRT) may therefore only fold
//!    when all lanes agree on the window; otherwise it must execute
//!    per item. The native backend folds per lane and never needs the
//!    fallback.
//!
//! Both rules are unit-tested here, at the `len == cap` and
//! `len == cap + 1` boundaries, so the fold logic stays falsifiable
//! even on machines without a PJRT runtime.

use crate::resources::adaptive::DecisionInputs;

/// Whether `len` records exceed the artifact's direct record slots and
/// the tail must be folded. Exactly-at-capacity fits without folding.
pub fn overflow_fold_needed(len: usize, cap_tasks: usize) -> bool {
    len > cap_tasks
}

/// How many records go into direct slots: all of them when they fit,
/// else `cap_tasks - 1` (the last slot is reserved for the fold).
pub fn direct_records(len: usize, cap_tasks: usize) -> usize {
    if overflow_fold_needed(len, cap_tasks) {
        cap_tasks.saturating_sub(1)
    } else {
        len
    }
}

/// Whether every input shares one (records, nodes, α) view, i.e. the
/// batch can ride the artifact's request lanes.
pub fn shares_record_view(inputs: &[DecisionInputs]) -> bool {
    inputs.windows(2).all(|w| {
        w[0].records == w[1].records && w[0].node_res == w[1].node_res && w[0].alpha == w[1].alpha
    })
}

/// Whether every lane in a chunk has the identical lifecycle window —
/// the precondition for a *shared* overflow fold (the synthetic record
/// is filtered and pinned by window, a per-lane quantity).
pub fn windows_identical(chunk: &[DecisionInputs]) -> bool {
    chunk
        .windows(2)
        .all(|w| w[0].win_start == w[1].win_start && w[0].win_end == w[1].win_end)
}

/// Fold the record tail for one lane: accumulate every tail record that
/// starts inside this lane's `[win_start, win_end)`. Sum-preserving for
/// that lane by construction.
pub fn fold_tail(
    records: &[(f32, f32, f32)],
    n_direct: usize,
    win_start: f32,
    win_end: f32,
) -> (f32, f32) {
    let (mut cpu, mut mem) = (0.0f32, 0.0f32);
    for &(rt, rc, rm) in &records[n_direct..] {
        if rt >= win_start && rt < win_end {
            cpu += rc;
            mem += rm;
        }
    }
    (cpu, mem)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(win: (f32, f32), records: Vec<(f32, f32, f32)>) -> DecisionInputs {
        DecisionInputs {
            records,
            win_start: win.0,
            win_end: win.1,
            req_cpu: 1000.0,
            req_mem: 2000.0,
            node_res: vec![(8000.0, 16384.0)],
            alpha: 0.8,
        }
    }

    #[test]
    fn exactly_at_capacity_needs_no_fold() {
        // The historical off-by-one: len == cap_tasks forced a pointless
        // fold even though every record fits a direct slot.
        assert!(!overflow_fold_needed(4, 4));
        assert_eq!(direct_records(4, 4), 4);
    }

    #[test]
    fn one_past_capacity_folds_into_the_last_slot() {
        assert!(overflow_fold_needed(5, 4));
        assert_eq!(direct_records(5, 4), 3);
    }

    #[test]
    fn under_capacity_is_all_direct() {
        assert!(!overflow_fold_needed(0, 4));
        assert!(!overflow_fold_needed(3, 4));
        assert_eq!(direct_records(3, 4), 3);
    }

    #[test]
    fn windows_identical_detects_cross_lane_divergence() {
        let recs = vec![(1.0, 100.0, 200.0)];
        let same = vec![
            input((0.0, 10.0), recs.clone()),
            input((0.0, 10.0), recs.clone()),
        ];
        assert!(windows_identical(&same));
        let diverged = vec![input((0.0, 10.0), recs.clone()), input((5.0, 20.0), recs)];
        assert!(!diverged.is_empty() && !windows_identical(&diverged));
        assert!(windows_identical(&[]));
    }

    #[test]
    fn shares_record_view_compares_records_nodes_alpha() {
        let recs = vec![(1.0, 100.0, 200.0)];
        let a = input((0.0, 10.0), recs.clone());
        let mut b = input((5.0, 20.0), recs.clone()); // windows may differ
        assert!(shares_record_view(&[a.clone(), b.clone()]));
        b.alpha = 0.9;
        assert!(!shares_record_view(&[a.clone(), b.clone()]));
        b.alpha = a.alpha;
        b.records = vec![(2.0, 100.0, 200.0)];
        assert!(!shares_record_view(&[a, b]));
    }

    #[test]
    fn fold_tail_filters_by_the_given_window() {
        let records = vec![
            (0.0, 1.0, 10.0), // direct slot
            (5.0, 2.0, 20.0), // tail, inside [0, 10)
            (50.0, 4.0, 40.0), // tail, outside
        ];
        assert_eq!(fold_tail(&records, 1, 0.0, 10.0), (2.0, 20.0));
        // A different lane window selects a different tail subset — the
        // reason a shared fold cannot serve divergent lanes.
        assert_eq!(fold_tail(&records, 1, 40.0, 60.0), (4.0, 40.0));
        assert_eq!(fold_tail(&records, 3, 0.0, 100.0), (0.0, 0.0));
    }
}
