//! Native vectorized decision backend — the compiled artifact's
//! pure-Rust interpreter twin.
//!
//! Executes the same fused decision graph the PJRT artifact encodes
//! (masked overlap sum → node aggregation → four-regime `alloc_eval`,
//! plus the `usage_integral` reduction) over SoA f32 lane buffers,
//! honoring the artifact's static capacities from `manifest.json`
//! (`model.py` defaults when no `artifacts/` directory exists, so the
//! backend is available unconditionally — including in CI, which has no
//! PJRT plugin). This is what finally makes the repo's batched
//! `decide_batch` raw-speed bet falsifiable: the lane-filling path runs
//! and is parity-tested on every `cargo test`, not only on machines
//! with a real XLA runtime.
//!
//! **Exactness.** On integral inputs (real workloads: milli-cores and
//! Mi are integers) every lane reproduces the scalar evaluator
//! bit-for-bit — the same contract `resources/evaluator.rs` documents
//! against the Pallas kernels, enforced by `rust/tests/backend_parity.rs`
//! and the committed golden vectors generated from
//! `python/compile/kernels/ref.py`.
//!
//! **Capacities.** `cap_batch` bounds the lane width of one fused
//! execution (larger batches run in `ceil(n / cap_batch)` chunks, like
//! the device path), and `cap_tasks` bounds the direct record slots —
//! overflow records are folded **per lane**, each lane filtering and
//! summing the tail against its *own* `[win_start, win_end)` window.
//! That per-lane fold is the rule the shared-buffer PJRT fold violated
//! (see `runtime/lanes.rs`); here it is exact for any mix of lane
//! windows, so the native backend never needs a per-item fallback for
//! divergent windows. `cap_nodes` is recorded for introspection only:
//! node aggregation is a streaming reduction with no per-node output
//! lanes, so the interpreter accepts any cluster size.

use std::path::Path;

use crate::metrics::UsageSample;
use crate::resources::adaptive::{DecisionBackend, DecisionInputs, DecisionOutputs};
use crate::resources::evaluator::{alloc_eval, ClusterAggregates};

use super::artifact::Manifest;
use super::lanes;

/// Static capacities mirroring `python/compile/model.py` (`CAP_TASKS`,
/// `CAP_NODES`, `CAP_BATCH`) — used when no `artifacts/manifest.json`
/// is present to read them from.
pub const DEFAULT_CAP_TASKS: usize = 512;
pub const DEFAULT_CAP_NODES: usize = 32;
pub const DEFAULT_CAP_BATCH: usize = 8;

/// The fused ARAS decision graph, interpreted natively over SoA lanes.
pub struct NativeBackend {
    cap_tasks: usize,
    cap_nodes: usize,
    cap_batch: usize,
    executions: u64,
    // Reusable SoA lane scratch (cap_batch wide) — the hot loop
    // allocates nothing.
    win_s: Vec<f32>,
    win_e: Vec<f32>,
    acc_cpu: Vec<f32>,
    acc_mem: Vec<f32>,
}

impl NativeBackend {
    /// Build with explicit capacities (tests, embedders).
    pub fn from_capacities(
        cap_tasks: usize,
        cap_nodes: usize,
        cap_batch: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            cap_tasks >= 1 && cap_nodes >= 1 && cap_batch >= 1,
            "native backend capacities must all be >= 1 \
             (got tasks={cap_tasks}, nodes={cap_nodes}, batch={cap_batch})"
        );
        Ok(Self {
            cap_tasks,
            cap_nodes,
            cap_batch,
            executions: 0,
            win_s: vec![0.0; cap_batch],
            win_e: vec![0.0; cap_batch],
            acc_cpu: vec![0.0; cap_batch],
            acc_mem: vec![0.0; cap_batch],
        })
    }

    /// Load capacities from an artifacts directory's `manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::from_capacities(manifest.cap_tasks, manifest.cap_nodes, manifest.cap_batch)
    }

    /// Load from the auto-discovered artifacts directory, or fall back
    /// to the `model.py` default capacities when none exists. Unlike
    /// the PJRT loader this never fails on a missing runtime — the
    /// interpreter *is* the runtime.
    pub fn load_default() -> anyhow::Result<Self> {
        match super::artifact::find_artifacts_dir() {
            Some(dir) => Self::load(&dir),
            None => Self::from_capacities(DEFAULT_CAP_TASKS, DEFAULT_CAP_NODES, DEFAULT_CAP_BATCH),
        }
    }

    /// Fused-graph executions performed (one per lane chunk).
    pub fn executions(&self) -> u64 {
        self.executions
    }

    pub fn capacities(&self) -> (usize, usize, usize) {
        (self.cap_tasks, self.cap_nodes, self.cap_batch)
    }

    /// Execute up to `cap_batch` requests sharing one record/node view
    /// in a single fused pass: records stream through every lane's
    /// window mask at once, then each lane runs the four-regime
    /// evaluation on its own aggregates.
    fn execute_chunk(&mut self, chunk: &[DecisionInputs]) -> Vec<DecisionOutputs> {
        assert!(!chunk.is_empty() && chunk.len() <= self.cap_batch);
        self.executions += 1;
        let shared = &chunk[0];
        let lanes_n = chunk.len();

        // Lane SoA: window bounds and overlap accumulators, seeded with
        // each lane's own demand (Alg. 1 line 8 start value).
        for (lane, inputs) in chunk.iter().enumerate() {
            self.win_s[lane] = inputs.win_start;
            self.win_e[lane] = inputs.win_end;
            self.acc_cpu[lane] = inputs.req_cpu;
            self.acc_mem[lane] = inputs.req_mem;
        }

        // Masked overlap sum, record-major: each direct-slot record is
        // tested against every lane's window in one pass, preserving
        // the scalar path's record-order accumulation per lane.
        let n_direct = lanes::direct_records(shared.records.len(), self.cap_tasks);
        for &(rt, rc, rm) in &shared.records[..n_direct] {
            // Branchless mask-multiply (the ref kernel's `w @ cpu` form,
            // auto-vectorizable): w*x is exactly x or +0.0, and adding
            // +0.0 never changes a non-negative accumulator, so this is
            // bit-identical to the scalar path's guarded adds.
            for lane in 0..lanes_n {
                let w = f32::from(u8::from(rt >= self.win_s[lane] && rt < self.win_e[lane]));
                self.acc_cpu[lane] += w * rc;
                self.acc_mem[lane] += w * rm;
            }
        }
        // Overflow tail: folded per lane, against that lane's window —
        // sum-preserving for every lane regardless of window mix.
        if lanes::overflow_fold_needed(shared.records.len(), self.cap_tasks) {
            for lane in 0..lanes_n {
                let (fc, fm) = lanes::fold_tail(
                    &shared.records,
                    n_direct,
                    self.win_s[lane],
                    self.win_e[lane],
                );
                self.acc_cpu[lane] += fc;
                self.acc_mem[lane] += fm;
            }
        }

        // Node aggregation (Alg. 2 output reduction): totals plus the
        // argmax-CPU node's residual pair, first index on ties —
        // identical to the scalar path and `node_aggregate_ref`.
        let mut total_cpu = 0.0f32;
        let mut total_mem = 0.0f32;
        let mut remax_cpu = f32::NEG_INFINITY;
        let mut remax_mem = 0.0f32;
        for &(c, m) in &shared.node_res {
            total_cpu += c;
            total_mem += m;
            if c > remax_cpu {
                remax_cpu = c;
                remax_mem = m;
            }
        }
        if shared.node_res.is_empty() {
            remax_cpu = 0.0;
        }
        let agg = ClusterAggregates {
            total_res_cpu: total_cpu,
            total_res_mem: total_mem,
            remax_cpu,
            remax_mem,
            alpha: shared.alpha,
        };

        (0..lanes_n)
            .map(|lane| {
                let (request_cpu, request_mem) = (self.acc_cpu[lane], self.acc_mem[lane]);
                let (alloc_cpu, alloc_mem) = alloc_eval(
                    chunk[lane].req_cpu,
                    chunk[lane].req_mem,
                    request_cpu,
                    request_mem,
                    &agg,
                );
                DecisionOutputs { alloc_cpu, alloc_mem, request_cpu, request_mem }
            })
            .collect()
    }
}

impl DecisionBackend for NativeBackend {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn decide(&mut self, inputs: &DecisionInputs) -> DecisionOutputs {
        self.execute_chunk(std::slice::from_ref(inputs))
            .into_iter()
            .next()
            .expect("one output per lane")
    }

    fn decide_batch(&mut self, inputs: &[DecisionInputs]) -> Vec<DecisionOutputs> {
        if inputs.len() > 1 && lanes::shares_record_view(inputs) {
            let mut out = Vec::with_capacity(inputs.len());
            for chunk in inputs.chunks(self.cap_batch) {
                out.extend(self.execute_chunk(chunk));
            }
            out
        } else {
            // Per-item record overlays (ARAS lookahead): each request
            // sees a different record view, so lanes cannot share one.
            inputs.iter().map(|i| self.decide(i)).collect()
        }
    }
}

/// The `usage_integral` kernel, interpreted natively: time-weighted mean
/// of a sampled rate curve via the masked trapezoidal reduction of
/// `usage_integral_ref` (`python/compile/kernels/ref.py`), in the same
/// f32 op order. Invalid samples contribute no area and do not extend
/// the span.
pub fn usage_integral(t: &[f32], y: &[f32], valid: &[f32]) -> f32 {
    assert!(t.len() == y.len() && y.len() == valid.len());
    let mut area = 0.0f32;
    let mut tmin = f32::INFINITY;
    let mut tmax = f32::NEG_INFINITY;
    for i in 0..t.len() {
        if i + 1 < t.len() {
            let dt = t[i + 1] - t[i];
            area += 0.5 * (y[i + 1] + y[i]) * dt * valid[i + 1] * valid[i];
        }
        if valid[i] > 0.0 {
            tmin = tmin.min(t[i]);
            tmax = tmax.max(t[i]);
        }
    }
    let span = tmax - tmin;
    if tmin.is_finite() && span > 0.0 {
        area / span.max(1e-9)
    } else {
        0.0
    }
}

/// Capacity-checked wrapper mirroring [`super::usage::UsageIntegral`]'s
/// API, so figure post-processing can swap the compiled artifact for
/// the interpreter without code changes.
pub struct NativeUsageIntegral {
    cap_samples: usize,
}

impl NativeUsageIntegral {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        Ok(Self { cap_samples: manifest.cap_samples.unwrap_or(4096) })
    }

    pub fn load_default() -> anyhow::Result<Self> {
        match super::artifact::find_artifacts_dir() {
            Some(dir) => Self::load(&dir),
            None => Ok(Self { cap_samples: 4096 }),
        }
    }

    /// Time-weighted mean of `pick` over the samples. Pads to the
    /// artifact's sample capacity exactly like the PJRT path (padding
    /// slots carry the last timestamp with a zero valid mask), so the
    /// two are interchangeable sample-for-sample.
    pub fn mean_rate(
        &self,
        samples: &[UsageSample],
        pick: impl Fn(&UsageSample) -> f64,
    ) -> anyhow::Result<f32> {
        let n = self.cap_samples;
        anyhow::ensure!(
            samples.len() <= n,
            "{} samples exceed artifact capacity {n}; regenerate artifacts",
            samples.len()
        );
        let last_t = samples.last().map(|s| s.t as f32).unwrap_or(0.0);
        let mut t = vec![last_t; n];
        let mut y = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for (i, s) in samples.iter().enumerate() {
            t[i] = s.t as f32;
            y[i] = pick(s) as f32;
            v[i] = 1.0;
        }
        Ok(usage_integral(&t, &y, &v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::adaptive::ScalarBackend;

    fn input(win: (f32, f32), records: Vec<(f32, f32, f32)>) -> DecisionInputs {
        DecisionInputs {
            records,
            win_start: win.0,
            win_end: win.1,
            req_cpu: 2000.0,
            req_mem: 4000.0,
            node_res: vec![(8000.0, 16384.0); 6],
            alpha: 0.8,
        }
    }

    #[test]
    fn capacities_must_be_positive() {
        assert!(NativeBackend::from_capacities(0, 32, 8).is_err());
        assert!(NativeBackend::from_capacities(512, 0, 8).is_err());
        assert!(NativeBackend::from_capacities(512, 32, 0).is_err());
        assert!(NativeBackend::from_capacities(1, 1, 1).is_ok());
    }

    #[test]
    fn single_decide_matches_scalar() {
        let recs: Vec<(f32, f32, f32)> = (0..30).map(|i| (i as f32, 500.0, 700.0)).collect();
        let inputs = input((0.0, 20.0), recs);
        let mut native = NativeBackend::load_default().unwrap();
        let a = ScalarBackend.decide(&inputs);
        let b = native.decide(&inputs);
        assert_eq!(a, b);
    }

    #[test]
    fn shared_view_batch_runs_in_one_chunk() {
        let recs: Vec<(f32, f32, f32)> = (0..16).map(|i| (i as f32, 500.0, 700.0)).collect();
        let batch: Vec<DecisionInputs> = (0..8)
            .map(|lane| input((lane as f32, lane as f32 + 10.0), recs.clone()))
            .collect();
        let mut native = NativeBackend::load_default().unwrap();
        let outs = native.decide_batch(&batch);
        assert_eq!(outs.len(), 8);
        assert_eq!(native.executions(), 1, "8 lanes fit one cap_batch=8 chunk");
        for (i, inp) in batch.iter().enumerate() {
            assert_eq!(outs[i], ScalarBackend.decide(inp), "lane {i}");
        }
    }

    #[test]
    fn divergent_record_views_fall_back_to_per_item() {
        let a = input((0.0, 10.0), vec![(1.0, 100.0, 200.0)]);
        let b = input((0.0, 10.0), vec![(2.0, 100.0, 200.0)]);
        let mut native = NativeBackend::load_default().unwrap();
        let outs = native.decide_batch(&[a.clone(), b.clone()]);
        assert_eq!(native.executions(), 2, "no shared view => one execution per item");
        assert_eq!(outs[0], ScalarBackend.decide(&a));
        assert_eq!(outs[1], ScalarBackend.decide(&b));
    }

    #[test]
    fn usage_integral_matches_hand_computation() {
        // Rate 1.0 for 10 s then 3.0 for 10 s: area = 10 + 20*... —
        // trapezoid: 0.5*(1+1)*10 + 0.5*(1+3)*10 = 10 + 20 = 30 over
        // span 20 => 1.5.
        let t = [0.0, 10.0, 20.0];
        let y = [1.0, 1.0, 3.0];
        let v = [1.0, 1.0, 1.0];
        assert_eq!(usage_integral(&t, &y, &v), 1.5);
    }

    #[test]
    fn usage_integral_degenerate_inputs_are_zero() {
        assert_eq!(usage_integral(&[], &[], &[]), 0.0);
        assert_eq!(usage_integral(&[5.0], &[0.7], &[1.0]), 0.0); // zero span
        let t = [0.0, 10.0];
        let y = [1.0, 1.0];
        assert_eq!(usage_integral(&t, &y, &[0.0, 0.0]), 0.0); // all padding
    }

    #[test]
    fn usage_integral_ignores_invalid_tail() {
        // Padding after the live samples (the mean_rate layout): no
        // area, no span extension.
        let t = [0.0, 10.0, 10.0, 10.0];
        let y = [1.0, 3.0, 0.0, 0.0];
        let v = [1.0, 1.0, 0.0, 0.0];
        assert_eq!(usage_integral(&t, &y, &v), 2.0);
    }
}
