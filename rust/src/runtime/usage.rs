//! PJRT-backed usage-curve analysis: the `usage_integral` artifact
//! (Pallas trapezoidal reduction) computing the paper's Resource Usage
//! metric over a sampled rate curve.
//!
//! `metrics::Collector::summarize` keeps its pure-Rust reduction (the
//! default); this module is the compiled-path twin used by the figure
//! post-processing and validated against it in `backend_parity.rs`.

use std::path::Path;

use crate::metrics::UsageSample;

use super::artifact::Manifest;

pub struct UsageIntegral {
    exe: xla::PjRtLoadedExecutable,
    cap_samples: usize,
}

impl UsageIntegral {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let file = manifest
            .file_of("usage_integral")
            .ok_or_else(|| anyhow::anyhow!("manifest has no usage_integral artifact"))?;
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(dir.join(file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(Self {
            exe: client.compile(&comp)?,
            cap_samples: manifest.cap_samples.unwrap_or(4096),
        })
    }

    pub fn load_default() -> anyhow::Result<Self> {
        let dir = super::artifact::find_artifacts_dir()
            .ok_or_else(|| anyhow::anyhow!("artifacts/ not found — run `make artifacts`"))?;
        Self::load(&dir)
    }

    /// Time-weighted mean of `pick` over the samples (PJRT execution).
    pub fn mean_rate(
        &self,
        samples: &[UsageSample],
        pick: impl Fn(&UsageSample) -> f64,
    ) -> anyhow::Result<f32> {
        let n = self.cap_samples;
        anyhow::ensure!(
            samples.len() <= n,
            "{} samples exceed artifact capacity {n}; regenerate artifacts",
            samples.len()
        );
        let last_t = samples.last().map(|s| s.t as f32).unwrap_or(0.0);
        let mut t = vec![last_t; n];
        let mut y = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for (i, s) in samples.iter().enumerate() {
            t[i] = s.t as f32;
            y[i] = pick(s) as f32;
            v[i] = 1.0;
        }
        let lits = [xla::Literal::vec1(&t), xla::Literal::vec1(&y), xla::Literal::vec1(&v)];
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?[0])
    }
}
