//! PJRT runtime bridge — load and execute the AOT artifacts.
//!
//! `make artifacts` lowers the Layer-2 JAX graph (with its Layer-1 Pallas
//! kernels) to HLO text; this module loads `artifacts/aras_decide.hlo.txt`
//! through the `xla` crate (PJRT CPU client), pads runtime state to the
//! artifact's static capacities, and exposes the result as a
//! [`crate::resources::adaptive::DecisionBackend`] so the ARAS policy can
//! run its hot-path math on the compiled module. Python never runs here.

pub mod artifact;
pub mod pjrt;
pub mod usage;

pub use artifact::{find_artifacts_dir, Manifest};
pub use pjrt::PjrtBackend;
pub use usage::UsageIntegral;
