//! Decision-backend runtimes — execute the compiled decision graph.
//!
//! `make artifacts` lowers the Layer-2 JAX graph (with its Layer-1
//! Pallas kernels) to HLO text plus a `manifest.json` of static
//! capacities. Two runtimes execute that graph shape:
//!
//! * [`native`] — a pure-Rust SoA interpreter for the fused decision
//!   graph, honoring the manifest capacities (`model.py` defaults when
//!   no `artifacts/` exists). Always available; runs and is
//!   parity-tested in CI.
//! * [`pjrt`] / [`usage`] — load the HLO artifacts through the `xla`
//!   crate's PJRT CPU client (a runtime-erroring stub in the offline
//!   vendored build), padding live state to the static shapes.
//!
//! Both are [`crate::resources::adaptive::DecisionBackend`]s; the
//! shared lane-filling and overflow-fold rules live in [`lanes`].
//! Backend selection (CLI `--backend`, config `"backend"`) goes through
//! `crate::resources::backends`. Python never runs here.

pub mod artifact;
pub mod lanes;
pub mod native;
pub mod pjrt;
pub mod usage;

pub use artifact::{find_artifacts_dir, Manifest};
pub use native::{NativeBackend, NativeUsageIntegral};
pub use pjrt::PjrtBackend;
pub use usage::UsageIntegral;
