//! Offline *stub* of the PJRT/XLA binding crate.
//!
//! The real binding links the PJRT CPU plugin and executes the AOT
//! artifacts produced by `python/compile/aot.py`. This toolchain image
//! has no registry access and no PJRT plugin, so this stub provides the
//! exact API surface `kubeadaptor::runtime` compiles against and fails
//! at the first runtime entry point ([`PjRtClient::cpu`]) with a clear
//! message. Everything downstream of client construction is therefore
//! unreachable; the types exist purely so the callers typecheck.
//!
//! Swapping in a real binding is a Cargo.toml one-liner — see
//! ARCHITECTURE.md §Runtime.

use std::fmt;
use std::path::Path;

/// Error type; converts into `anyhow::Error` via `?`.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: this build uses the offline xla stub \
         (vendor/xla). Install a real PJRT/XLA binding to run compiled \
         artifacts; the scalar backend covers all experiments."
            .to_string(),
    )
}

/// A host literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple4(&self) -> Result<(Literal, Literal, Literal, Literal)> {
        Err(unavailable())
    }
}

impl From<f32> for Literal {
    fn from(_value: f32) -> Literal {
        Literal
    }
}

/// A device buffer returned by execution (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// An HLO module parsed from text (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// A computation wrapping an HLO module (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (stub; never constructible at runtime).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// The PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Always errors in the stub — the one runtime gate every caller
    /// passes through first.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline xla stub"));
    }
}
