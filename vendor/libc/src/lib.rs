//! Offline shim for the `libc` crate: only the `signal(2)` surface the
//! `kubeadaptor` binary uses to die quietly on SIGPIPE.

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type sighandler_t = usize;

/// Default signal disposition.
pub const SIG_DFL: sighandler_t = 0;
/// Broken pipe (write to a closed reader), POSIX number on Linux.
pub const SIGPIPE: c_int = 13;

#[cfg(unix)]
extern "C" {
    pub fn signal(signum: c_int, handler: sighandler_t) -> sighandler_t;
}

/// No-op fallback so the crate still compiles off-unix.
#[cfg(not(unix))]
pub unsafe fn signal(_signum: c_int, _handler: sighandler_t) -> sighandler_t {
    SIG_DFL
}
