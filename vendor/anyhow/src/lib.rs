//! Offline shim for the `anyhow` crate: the subset this workspace uses
//! (`Result`, `Error`, `anyhow!`, `bail!`, `ensure!`), API-compatible so
//! the real crate can be swapped back in when a registry is available.
//!
//! Like the real `anyhow::Error`, [`Error`] deliberately does NOT
//! implement `std::error::Error` itself — that keeps the blanket
//! `From<E: std::error::Error>` conversion (which powers `?`) free of
//! overlap with the standard library's reflexive `From<T> for T`.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with a defaultable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error wrapper with source-chain formatting.
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

/// Internal payload for message-only errors built by [`anyhow!`].
struct MessageError(String);

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(Box::new(MessageError(message.to_string())))
    }

    /// Build from any concrete error type.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error(Box::new(error))
    }

    /// Iterate the source chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> = Some(self.0.as_ref());
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.chain().last().expect("chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        // `{:#}` renders the whole cause chain, like the real anyhow.
        if f.alternate() {
            for cause in self.chain().skip(1) {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let mut causes = self.chain().skip(1).peekable();
        if causes.peek().is_some() {
            write!(f, "\n\nCaused by:")?;
            for cause in causes {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error(Box::new(error))
    }
}

impl AsRef<dyn StdError + Send + Sync> for Error {
    fn as_ref(&self) -> &(dyn StdError + Send + Sync + 'static) {
        self.0.as_ref()
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 7)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/here")?)
        }
        assert!(io().is_err());
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn alternate_format_prints_chain() {
        let inner = std::io::Error::new(std::io::ErrorKind::Other, "inner");
        let e = Error::new(inner);
        assert!(format!("{e:#}").contains("inner"));
    }
}
